"""Unified, device-resident write statistics for the memory substrate.

ONE schema for every backend (oracle, lanes_ref, pallas, exact): a frozen
pytree dataclass of 0-d device arrays that

  * lives inside jit — the serving burst carries a ``WriteStats`` through
    ``lax.scan`` and adds one per fused write step;
  * reduces losslessly across leaves/slots/steps with ``+`` (counters and
    energy sum; latency is a max — parallel driver banks are bounded by the
    slowest used driver, paper Table 1 semantics);
  * crosses to the host exactly once, via ``jax.device_get`` /
    ``host_dict()``, when a report is assembled.

``soft_strikes`` counts post-write retention upsets injected by the
optional soft-error hook of ``WritePlan`` (zero when the hook is off), so
the schema is identical whether or not the hook runs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

#: bits_total accumulates as TWO int32 limbs (hi * 2^30 + lo): a single f32
#: running total silently stops growing once it passes ~2^24x the per-write
#: increment (a long serving run writes terabits), and int64 is unavailable
#: without jax x64. Limb arithmetic keeps the count exact to 2^61 bits.
_LIMB = 1 << 30


def _bits_limbs(bits: int) -> Tuple[jax.Array, jax.Array]:
    """Static per-write bit count -> (hi, lo) int32 limb constants."""
    hi, lo = divmod(int(bits), _LIMB)
    return jnp.asarray(hi, jnp.int32), jnp.asarray(lo, jnp.int32)


@dataclasses.dataclass(frozen=True)
class WriteStats:
    energy_pj: jax.Array    # f32: realized write energy
    latency_ns: jax.Array   # f32: slowest used driver (max-reduced)
    flips01: jax.Array      # i32: 0->1 writes (P->AP, the expensive ones)
    flips10: jax.Array      # i32: 1->0 writes
    errors: jax.Array       # i32: failed flips (bit kept its old value)
    soft_strikes: jax.Array  # i32: post-write retention upsets (hook)
    bits_hi: jax.Array      # i32: addressed element bits, high limb (2^30s)
    bits_lo: jax.Array      # i32: addressed element bits, low limb

    @classmethod
    def zero(cls) -> "WriteStats":
        z32 = jnp.zeros((), jnp.float32)
        zi = jnp.zeros((), jnp.int32)
        return cls(energy_pj=z32, latency_ns=z32, flips01=zi, flips10=zi,
                   errors=zi, soft_strikes=zi, bits_hi=zi, bits_lo=zi)

    @classmethod
    def for_bits(cls, bits: int, **kw) -> "WriteStats":
        """Zero stats carrying a static addressed-bit count; backends
        override the realized fields via keyword arguments."""
        hi, lo = _bits_limbs(bits)
        return dataclasses.replace(cls.zero(), bits_hi=hi, bits_lo=lo, **kw)

    def __add__(self, other: "WriteStats") -> "WriteStats":
        # each operand's lo limb is < 2^30 by construction, so the sum
        # fits int32; normalize the single possible carry
        lo = self.bits_lo + other.bits_lo
        carry = (lo >= _LIMB).astype(jnp.int32)
        return WriteStats(
            energy_pj=self.energy_pj + other.energy_pj,
            latency_ns=jnp.maximum(self.latency_ns, other.latency_ns),
            flips01=self.flips01 + other.flips01,
            flips10=self.flips10 + other.flips10,
            errors=self.errors + other.errors,
            soft_strikes=self.soft_strikes + other.soft_strikes,
            bits_hi=self.bits_hi + other.bits_hi + carry,
            bits_lo=lo - carry * _LIMB,
        )

    @property
    def bits_written(self) -> jax.Array:
        return self.flips01 + self.flips10

    @property
    def bits_total(self):
        """Recombined addressed-bit count. Exact (float64/Python) on
        host-side instances; f32 under a trace — prefer the limbs or
        ``host_dict()`` when exactness matters at scale."""
        return self.bits_hi * float(_LIMB) + self.bits_lo

    def host_dict(self) -> Dict[str, Any]:
        """Sync to the host (the ONE transfer) and derive the report
        quantities. Idempotent on already-synced (numpy) instances."""
        h = jax.device_get(self)
        bits_written = int(h.flips01) + int(h.flips10)
        bits_total = int(h.bits_hi) * _LIMB + int(h.bits_lo)
        return {
            "energy_pj": float(h.energy_pj),
            "latency_ns": float(h.latency_ns),
            "flips01": int(h.flips01),
            "flips10": int(h.flips10),
            "bits_written": bits_written,
            "bits_total": bits_total,
            "bit_errors": int(h.errors),
            "soft_strikes": int(h.soft_strikes),
            "write_skip_rate": (1.0 - bits_written / bits_total
                                if bits_total else 0.0),
            "ber_realized": int(h.errors) / max(1, bits_written),
        }


jax.tree_util.register_dataclass(
    WriteStats,
    data_fields=["energy_pj", "latency_ns", "flips01", "flips10", "errors",
                 "soft_strikes", "bits_hi", "bits_lo"],
    meta_fields=[],
)
