"""WritePlan: resolve-once write policy for a pytree of memory regions.

The plan is the reproduction's EXTENT table row + 4-driver bank for one
cache/state *shape*: built exactly once (from abstract leaves — no device
data needed), it captures

  * which leaves go through the approximate driver and at which static
    level (the pytree policy, e.g. K@MID / V@LOW / recurrent-state EXACT),
  * the calibrated per-bit driver vectors for every (leaf, quality-floor)
    combination — plain array OPERANDS of the compiled write, so an
    ``ExtentTable``/``QualityController`` floor change between bursts swaps
    constants and NEVER retraces,
  * the RNG stream layout: leaf ``i`` folds ``i`` into the step key, and
    the lane backends hash FLAT lane indices, so results are invariant to
    block partitioning (the bit-parity contract continuous batching rests
    on — see tests/test_extent_parity.py),
  * the column-scoped decode write: leaves with a sequence axis write only
    the ring column at ``pos % C`` per slot — O(token) lane work per decode
    step instead of O(cache), with accounting identical to the full diff
    (everything outside the column is bit-unchanged => zero under CMP),
  * an optional post-write soft-error hook (retention upsets at
    ``soft_error_ber``; the hardened driver protects sign/exponent bits),
    surfaced through ``WriteStats.soft_strikes``.

Composition rule for floors: effective level = max(static policy, floor) —
quality hints RAISE fidelity above the static policy, never lower it, and
EXACT-pinned leaves are not in the plan at all.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import write_driver
from repro.core.approx_store import inject_soft_errors
from repro.core.priority import (Priority, bitplane_priorities, bits_of,
                                 kv_cache_policy, uint_type)
from repro.memory import address as addr_mod
from repro.memory.backends import Backend, LeafVectors, get_backend
from repro.memory.rng_streams import SOFT_ERROR_OFFSET as _SOFT_KEY_OFFSET
from repro.memory.stats import WriteStats


def leaf_vectors(dtype, level, cfg: Optional[write_driver.DriverConfig] = None,
                 *, per_bit: bool = True) -> LeafVectors:
    """Resolve one (element dtype, effective level) pair to driver operands.

    lru-cached and forced to compile-time evaluation (driver calibration is
    Python-float code), so it is safe to call while tracing an enclosing
    jit — the resolve-once half of the WritePlan contract."""
    return _leaf_vectors(jnp.dtype(dtype), Priority.coerce(level), cfg,
                         per_bit)


@functools.lru_cache(maxsize=512)
def _leaf_vectors(dtype, level: Priority,
                  cfg: Optional[write_driver.DriverConfig],
                  per_bit: bool) -> LeafVectors:
    with jax.ensure_compile_time_eval():
        table = write_driver.level_table(cfg or write_driver.DriverConfig())
        tb = {k: np.asarray(v) for k, v in table.items()}
        nbits = bits_of(dtype)
        if per_bit:
            codes = bitplane_priorities(dtype, level)
        else:
            codes = np.full((nbits,), int(level), np.int32)
        lat = tb["lat"][codes]
        lanes: Tuple[Optional[jax.Array], ...] = (None, None, None, None)
        if per_bit and dtype.itemsize in (1, 2, 4):
            from repro.kernels.extent_write import ops as xops
            lanes = xops.level_vectors(dtype, level, cfg)
        return LeafVectors(
            wer01=jnp.asarray(tb["wer01"][codes], jnp.float32),
            wer10=jnp.asarray(tb["wer10"][codes], jnp.float32),
            eb01=jnp.asarray(tb["e01"][codes], jnp.float32),
            eb10=jnp.asarray(tb["e10"][codes], jnp.float32),
            lat=jnp.asarray(lat, jnp.float32),
            lat_max=jnp.asarray(float(lat.max()), jnp.float32),
            thr01=lanes[0], thr10=lanes[1], le01=lanes[2], le10=lanes[3])


def _default_approx_if(leaf, tag: Priority) -> bool:
    """Engine rule: floating leaves below EXACT go through the approximate
    driver; integer/control leaves and EXACT-pinned leaves bypass it."""
    return jnp.issubdtype(leaf.dtype, jnp.floating) and tag != Priority.EXACT


def _stuck_gate(old, new, worn):
    """Stuck-at gating for worn physical rows: elements under ``worn``
    keep their stored value (the row no longer accepts writes) and every
    bit the gated write *would* have changed counts as a failed write.
    Returns (gated_new, lost_bit_count). Because the gated new equals the
    stored old on worn rows, the downstream CMP diff write charges zero
    flips/energy there — the controller skips rows its bad-row table
    names, but the data loss is booked in ``WriteStats.errors``."""
    ut = uint_type(old.dtype)
    d = (jax.lax.bitcast_convert_type(old, ut)
         ^ jax.lax.bitcast_convert_type(new, ut))
    lost = jnp.sum(jnp.where(worn, jax.lax.population_count(d), ut(0))
                   .astype(jnp.int32), dtype=jnp.int32)
    return jnp.where(worn, old, new), lost


def _soft_error_hook(key, x, ber: float, hardened: bool):
    """Post-write retention upsets + the strike count (popcount of the
    flipped-bit mask)."""
    y = inject_soft_errors(key, x, ber, protect_exponent=hardened)
    ut = uint_type(x.dtype)
    d = (jax.lax.bitcast_convert_type(x, ut)
         ^ jax.lax.bitcast_convert_type(y, ut))
    strikes = jnp.sum(jax.lax.population_count(d).astype(jnp.int32),
                      dtype=jnp.int32)
    return y, strikes


@dataclasses.dataclass
class WritePlan:
    """Resolved write policy for one pytree structure (see module doc)."""
    backend: Backend
    treedef: Any
    leaf_levels: Tuple[Optional[Priority], ...]
    leaf_seq_axis: Tuple[Optional[int], ...]
    batch_axis: int = 1
    soft_error_ber: float = 0.0
    soft_error_hardened: bool = True
    #: physical addressing layer (repro.memory.address): None = no remap,
    #: no stuck-at gating — the exact pre-address data path.
    address_spec: Optional[addr_mod.AddressSpec] = None
    floor_vectors: Dict[Priority, Tuple[Optional[LeafVectors], ...]] = (
        dataclasses.field(default_factory=dict))
    _jit_write: Any = dataclasses.field(default=None, repr=False,
                                        compare=False)

    # ------------------------------------------------------------ construction
    @classmethod
    def for_tree(cls, tree: Any, *,
                 policy: Callable[..., Any] = kv_cache_policy,
                 backend: str | Backend = "lanes_ref",
                 axes: Any = None,
                 batch_axis: int = 1,
                 soft_error_ber: float = 0.0,
                 soft_error_hardened: bool = True,
                 address_spec: Optional[addr_mod.AddressSpec] = None,
                 driver_cfg: Optional[write_driver.DriverConfig] = None,
                 approx_if: Callable[[Any, Priority], bool]
                 = _default_approx_if) -> "WritePlan":
        """Resolve ``policy`` over ``tree`` (arrays or ShapeDtypeStructs —
        only structure/shape/dtype are read) into a plan.

        ``axes``: optional same-structure tree of logical-axis tuples (the
        model API's ``cache_axes()``); leaves whose tuple contains
        ``"kv_seq"`` get the column-scoped decode write. ``backend`` is a
        registry name or an instance.
        """
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        levels = []
        for path, leaf in flat:
            tag = Priority.coerce(policy(path, leaf))
            levels.append(tag if approx_if(leaf, tag) else None)
        if axes is not None:
            flat_axes = treedef.flatten_up_to(axes)
            seq_axis = tuple(
                ax.index("kv_seq")
                if isinstance(ax, tuple) and "kv_seq" in ax else None
                for ax in flat_axes)
        else:
            seq_axis = (None,) * len(flat)
        floor_vectors = {
            floor: tuple(
                leaf_vectors(leaf.dtype, max(lvl, floor), driver_cfg)
                if lvl is not None else None
                for (_, leaf), lvl in zip(flat, levels))
            for floor in Priority}
        be = backend if not isinstance(backend, str) else get_backend(backend)
        return cls(backend=be, treedef=treedef, leaf_levels=tuple(levels),
                   leaf_seq_axis=seq_axis, batch_axis=batch_axis,
                   soft_error_ber=soft_error_ber,
                   soft_error_hardened=soft_error_hardened,
                   address_spec=address_spec,
                   floor_vectors=floor_vectors)

    # ------------------------------------------------------ address layer
    def rotatable(self) -> Tuple[bool, ...]:
        """Per-leaf flag: does the wear-leveling rotation apply? Only
        approximate leaves with a ring (sequence) axis have a column
        permutation to rotate."""
        return tuple(lvl is not None and ax is not None
                     for lvl, ax in zip(self.leaf_levels,
                                        self.leaf_seq_axis))

    def identity_address(self) -> addr_mod.AddressState:
        """The identity permutation for this plan's leaf count —
        bit-identical to running with no address layer at all."""
        return addr_mod.AddressState.identity(len(self.leaf_levels))

    def _worn_elem(self, i: int, leaf, shifts, worn) -> Optional[jax.Array]:
        """Element-space stuck-at mask for leaf ``i`` under the address
        operands, or None when gating is off/irrelevant."""
        if worn is None or self.address_spec is None:
            return None
        return addr_mod.worn_element_mask(
            worn[i], shifts[i], leaf.shape, self.leaf_seq_axis[i],
            self.batch_axis, self.address_spec)

    def migration_cost(self, tree: Any) -> Tuple[float, int]:
        """Host constants (energy_pj, bits) of ONE start-gap migration:
        one ``group_cols``-wide row group per ring leaf copied through the
        controller's row buffer, every bit re-driven at the mean of the
        plan's static 0→1/1→0 per-plane write prices. The ONE source of
        the remap pricing — the serving scheduler and the endurance
        benchmark both book rotations through it."""
        assert self.address_spec is not None, "plan has no address layer"
        import numpy as np
        vectors = self.vectors_for(Priority.LOW)
        pj, bits = 0.0, 0
        flat = jax.tree.leaves(tree)
        for i, (leaf, lvl, ax) in enumerate(zip(flat, self.leaf_levels,
                                                self.leaf_seq_axis)):
            if lvl is None or ax is None:
                continue
            C = leaf.shape[ax]
            elems = leaf.size // C * min(self.address_spec.group_cols, C)
            eb = (np.asarray(vectors[i].eb01)
                  + np.asarray(vectors[i].eb10)) / 2.0
            pj += float(elems) * float(eb.sum())
            bits += elems * bits_of(leaf.dtype)
        return pj, bits

    def alias_saving(self, tree: Any, cols: int) -> Tuple[float, int]:
        """Host constants (energy_pj, bits) of driving ``cols`` leading
        ring columns of ONE slot across the sequence-axis leaves, every
        bit priced at the mean of the plan's static 0→1/1→0 per-plane
        write prices — the modeled full-drive cost of the columns a
        prefix link skips. The ONE source of the prefix-cache pricing:
        the serving scheduler books both its *saved-write* estimate and
        its *copy-on-write* materialization charge through this (the same
        columns, the same price — a CoW pays back exactly what the link
        was credited)."""
        import numpy as np
        vectors = self.vectors_for(Priority.LOW)
        pj, bits = 0.0, 0
        flat = jax.tree.leaves(tree)
        for i, (leaf, lvl, ax) in enumerate(zip(flat, self.leaf_levels,
                                                self.leaf_seq_axis)):
            if lvl is None or ax is None:
                continue
            C = leaf.shape[ax]
            B = leaf.shape[self.batch_axis]
            elems = leaf.size // (C * B) * min(int(cols), C)
            eb = (np.asarray(vectors[i].eb01)
                  + np.asarray(vectors[i].eb10)) / 2.0
            pj += float(elems) * float(eb.sum())
            bits += elems * bits_of(leaf.dtype)
        return pj, bits

    # -------------------------------------------------------------- operands
    def vectors_for(self, floor: Priority = Priority.LOW
                    ) -> Tuple[Optional[LeafVectors], ...]:
        """Per-leaf driver-vector operands for one quality floor. LOW is
        the identity floor: the static policy alone. The tuples share one
        pytree structure across floors, so swapping them between compiled
        calls never retraces."""
        return self.floor_vectors[Priority.coerce(floor)]

    # ----------------------------------------------------------- write paths
    def _leaf_write(self, key, i: int, old, new,
                    lv: LeafVectors) -> Tuple[jax.Array, WriteStats]:
        """One leaf through the backend + the optional soft-error hook —
        the single place the per-leaf write protocol (RNG fold-in schedule
        included) lives."""
        stored, st = self.backend.leaf_write(jax.random.fold_in(key, i),
                                             old, new, lv)
        if self.soft_error_ber > 0.0:
            k_soft = jax.random.fold_in(key, _SOFT_KEY_OFFSET + i)
            stored, strikes = _soft_error_hook(
                k_soft, stored, self.soft_error_ber,
                self.soft_error_hardened)
            st = dataclasses.replace(st,
                                     soft_strikes=st.soft_strikes + strikes)
        return stored, st

    def _alias_keep(self, i: int, leaf, alias_cols) -> Optional[jax.Array]:
        """Column-alias mask for leaf ``i``: True on the leading
        ``alias_cols[slot]`` ring columns (broadcastable to the leaf).
        Aliased columns are *linked* to columns already resident elsewhere
        in the array (serve/prefix.py): the write carries the stored value
        through unchanged, so CMP charges zero energy/flips/WER there —
        the skipped write never happens. None when aliasing is off or the
        leaf has no ring axis (nothing to link column-wise)."""
        ax = self.leaf_seq_axis[i]
        if alias_cols is None or ax is None:
            return None
        ishape = [1] * leaf.ndim
        ishape[self.batch_axis] = alias_cols.shape[0]
        return (jax.lax.broadcasted_iota(jnp.int32, leaf.shape, ax)
                < alias_cols.reshape(ishape))

    def write(self, key, old_tree: Any, new_tree: Any,
              vectors: Optional[Sequence] = None,
              addr: Optional[Tuple[jax.Array, Optional[jax.Array]]] = None,
              alias_cols: Optional[jax.Array] = None
              ) -> Tuple[Any, WriteStats]:
        """Jit-resident diff-write of a full tree (or a row subset with the
        same structure); returns (stored_tree, WriteStats). ``vectors`` is
        a per-flat-leaf operand tuple, normally from ``vectors_for``.
        ``addr`` is the optional physical-addressing operand pair
        ``(shifts (L,) i32, worn (L, G) bool-or-None)``: elements backed by
        worn physical row groups are stuck-at (kept old, lost flips booked
        to ``errors``). With identity shifts and no worn rows the stored
        bits and stats are bit-identical to ``addr=None``.

        ``alias_cols`` is the optional (B,) i32 column-alias OPERAND of the
        prefix cache: per slot, the leading ``alias_cols[b]`` ring columns
        of every sequence-axis leaf are column-*linked* — the stored (old)
        value is kept bit-for-bit and the write is skipped, so those
        columns cost exactly zero energy/flips/WER under CMP. The RNG
        streams hash flat logical element indices and every per-element
        decision is element-local, so all NON-aliased elements store bits
        identical to the unaliased call; an all-zero ``alias_cols`` is a
        bit-exact identity with ``alias_cols=None``."""
        if vectors is None:
            vectors = self.vectors_for(Priority.LOW)
        shifts, worn = addr if addr is not None else (None, None)
        flat_old, treedef = jax.tree.flatten(old_tree)
        flat_new = treedef.flatten_up_to(new_tree)
        stored = []
        acc = WriteStats.zero()
        for i, (o, n, lvl) in enumerate(zip(flat_old, flat_new,
                                            self.leaf_levels)):
            if lvl is None:
                stored.append(n)  # EXACT fast path (recurrent states, ints)
                continue
            keep = self._alias_keep(i, o, alias_cols)
            if keep is not None:
                # linked columns re-store the resident bits: identical
                # old/new means the CMP diff write skips them entirely
                n = jnp.where(keep, o, n)
            wm = self._worn_elem(i, o, shifts, worn)
            lost = None
            if wm is not None:
                n, lost = _stuck_gate(o, n, wm)
            s, st = self._leaf_write(key, i, o, n, vectors[i])
            if lost is not None:
                st = dataclasses.replace(st, errors=st.errors + lost)
            stored.append(s)
            acc = acc + st
        return treedef.unflatten(stored), acc

    def write_columns(self, key, old_tree: Any, new_tree: Any,
                      pos: jax.Array,
                      vectors: Optional[Sequence] = None,
                      addr: Optional[Tuple[jax.Array,
                                           Optional[jax.Array]]] = None,
                      alias_cols: Optional[jax.Array] = None
                      ) -> Tuple[Any, WriteStats]:
        """Column-scoped decode diff-write: leaves with a sequence axis
        write only the ring column at ``pos % C`` (per slot along
        ``batch_axis``); other approximate leaves fall back to the full
        diff. Flip/energy stats are identical to ``write`` — the rest of
        the tree is bit-unchanged after a decode step, so CMP contributes
        exactly zero there — but the per-step cost drops from O(cache) to
        O(token) lane work. ``pos`` is the (B,) position vector.

        ``addr``: optional ``(shifts, worn)`` physical-addressing operands
        (see ``write``). The written column's *address* maps through the
        rotation to find its physical row group; a slot whose target group
        is worn has its column write inhibited (stuck-at, lost flips in
        ``errors``). The RNG stream is untouched — it hashes the gathered
        column tensor's flat indices, which do not depend on the address —
        so identity shifts reproduce ``addr=None`` bit-for-bit.

        ``alias_cols``: optional (B,) i32 column-alias operand (see
        ``write``) — a slot whose target column lies inside its linked
        prefix (``pos[b] < alias_cols[b]``) keeps the resident bits and
        skips the write at zero cost. All-zero alias is a bit-exact
        identity with ``alias_cols=None``."""
        if vectors is None:
            vectors = self.vectors_for(Priority.LOW)
        shifts, worn = addr if addr is not None else (None, None)
        gate = worn is not None and self.address_spec is not None
        flat_old, treedef = jax.tree.flatten(old_tree)
        flat_new = treedef.flatten_up_to(new_tree)
        stored = []
        acc = WriteStats.zero()
        for i, (o, n, lvl) in enumerate(zip(flat_old, flat_new,
                                            self.leaf_levels)):
            if lvl is None:
                stored.append(n)
                continue
            ax = self.leaf_seq_axis[i]
            lost = None
            if ax is None:
                wm = self._worn_elem(i, o, shifts, worn)
                if wm is not None:
                    n, lost = _stuck_gate(o, n, wm)
                s, st = self._leaf_write(key, i, o, n, vectors[i])
                if lost is not None:
                    st = dataclasses.replace(st, errors=st.errors + lost)
                stored.append(s)
                acc = acc + st
                continue
            C = o.shape[ax]
            ishape = [1] * o.ndim
            ishape[self.batch_axis] = pos.shape[0]
            idx = (pos % C).reshape(ishape)
            gshape = o.shape[:ax] + (1,) + o.shape[ax + 1:]
            idx_g = jnp.broadcast_to(idx, gshape)
            o_col = jnp.take_along_axis(o, idx_g, axis=ax)
            n_col = jnp.take_along_axis(n, idx_g, axis=ax)
            if alias_cols is not None:
                keep = (pos < alias_cols).reshape(ishape)
                n_col = jnp.where(keep, o_col, n_col)
            if gate:
                wm = addr_mod.worn_slot_mask(
                    worn[i], pos, shifts[i], C,
                    self.address_spec).reshape(ishape)
                n_col, lost = _stuck_gate(o_col, n_col, wm)
            s_col, st = self._leaf_write(key, i, o_col, n_col, vectors[i])
            if lost is not None:
                st = dataclasses.replace(st, errors=st.errors + lost)
            hit = jax.lax.broadcasted_iota(jnp.int32, o.shape, ax) == idx
            stored.append(jnp.where(hit, s_col, n))
            acc = acc + st
        return treedef.unflatten(stored), acc

    def jitted_write(self):
        """Compiled ``write`` (cached on the plan, shared by every
        MemoryRegion that replaces itself functionally around this plan)."""
        if self._jit_write is None:
            self._jit_write = jax.jit(
                lambda k, o, n, v: self.write(k, o, n, v))
        return self._jit_write

    # ------------------------------------------------------- shape metadata
    def approx_bits(self, tree: Any) -> int:
        """Total bits of the approximate leaves — static shape metadata."""
        flat = jax.tree.leaves(tree)
        return sum(l.size * bits_of(l.dtype)
                   for l, lvl in zip(flat, self.leaf_levels)
                   if lvl is not None)

    def decode_bits(self, tree: Any) -> int:
        """Approximate bits one decode step addresses: the written ring
        column per sequence-axis leaf, whole leaves otherwise."""
        flat = jax.tree.leaves(tree)
        total = 0
        for l, lvl, ax in zip(flat, self.leaf_levels, self.leaf_seq_axis):
            if lvl is None:
                continue
            sz = l.size if ax is None else l.size // l.shape[ax]
            total += sz * bits_of(l.dtype)
        return total


# ---------------------------------------------------------------------------
# single-tensor convenience entry (examples, checkpoints, benchmarks, tests)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=32)
def _leaf_jit(backend_obj: Backend):
    # keyed on the backend INSTANCE, not its registry name: re-registering
    # a name makes get_backend hand out a fresh instance, which gets a
    # fresh jit here — an override is never shadowed by a stale closure
    return jax.jit(backend_obj.leaf_write)


def write(key, old, new, *, level: Priority | int | str = Priority.LOW,
          backend: str = "lanes_ref",
          driver_cfg: Optional[write_driver.DriverConfig] = None
          ) -> Tuple[jax.Array, WriteStats]:
    """Unified single-tensor EXTENT write through a registered backend.

    Returns (stored, WriteStats). The level resolves through the same
    ``leaf_vectors`` cache as WritePlan, and the vectors ride as operands
    of one jitted call per backend — a level sweep reuses one compiled
    executable."""
    lv = leaf_vectors(old.dtype, level, driver_cfg)
    return _leaf_jit(get_backend(backend))(key, old, new, lv)
