"""Trace ⇄ scheduler adapters: replay, recording, and the report joiner.

``TraceSource`` is the trace-iterator arrival source the scheduler
consumes alongside its materialized-list default (see
``serve/scheduler.py``'s arrival-source protocol): it answers the two
host-side questions scheduling needs — "when does the next request
arrive" and "hand me the next request" — and materializes prompt arrays
only at admission time, so a million-event trace never sits on the device
as a million prompt tensors. The admission order is (arrival, rid),
identical to the list path, which is what keeps replay-vs-synthetic
bit-parity intact.

``record_requests`` is the inverse: any request stream (the synthetic
default included) becomes a trace, so any run is replayable. One host
read per request — eager pre-serve code, not scheduler-event work.

``join_reports`` merges per-mix serve reports (energy / latency / BER /
wear / lifetime / prefix ledgers) into one flat frontier table for the
workload_mixes benchmark and the BENCH json trajectory.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.priority import Priority
from repro.serve.scheduler import Request
from repro.workload.trace import Trace, TraceEvent, from_requests, \
    validate_trace


def _materialize(ev: TraceEvent, cfg,
                 quality_override: Optional[str] = None) -> Request:
    """TraceEvent -> scheduler Request: prompt arrays built here (the one
    place trace data becomes device data). Multimodal prompt leaves are
    regenerated from the recorded modal_seed with the synthetic stream's
    recipe — same key, same shape, same bits."""
    prompt: Dict[str, jax.Array] = {
        "tokens": jnp.asarray([list(ev.tokens)], jnp.int32)}
    if cfg.family in ("vlm", "audio"):
        if ev.modal_seed is None:
            raise ValueError(
                f"rid {ev.rid}: family {cfg.family!r} needs a modal_seed "
                "to regenerate non-token prompt leaves")
        k = jax.random.PRNGKey(ev.modal_seed)
        if cfg.family == "vlm":
            prompt["image_embeds"] = jax.random.normal(
                k, (1, cfg.num_image_tokens, cfg.vision_dim), jnp.float32)
        else:
            prompt["frames"] = jax.random.normal(
                k, (1, 24, cfg.d_model), jnp.float32)
    q = quality_override if quality_override is not None else ev.quality
    return Request(
        rid=ev.rid, prompt=prompt, new_tokens=ev.new_tokens,
        arrival=ev.arrival, app_id=ev.app_id,
        quality=Priority.coerce(q) if q is not None else None,
        session=ev.session, modal_seed=ev.modal_seed)


class TraceSource:
    """Trace-iterator arrival source for ``ContinuousScheduler.run``.

    Implements the scheduler's arrival-source protocol
    (``next_arrival`` / ``popleft`` / truthiness) over validated,
    (arrival, rid)-sorted trace events. Prompts materialize lazily in
    ``popleft`` — peeking the next arrival is pure host metadata, so the
    scheduler's one-sync-per-event discipline is untouched.

    ``quality_override`` forces every request to one quality level (the
    workload_mixes extent-floor knob)."""

    def __init__(self, trace: Trace, cfg,
                 quality_override: Optional[str] = None):
        self.trace = validate_trace(trace)
        self.cfg = cfg
        self.quality_override = quality_override
        self._i = 0

    def __bool__(self) -> bool:
        return self._i < len(self.trace.events)

    def __len__(self) -> int:
        return len(self.trace.events) - self._i

    def next_arrival(self) -> Optional[int]:
        if not self:
            return None
        return self.trace.events[self._i].arrival

    def popleft(self) -> Request:
        ev = self.trace.events[self._i]
        self._i += 1
        return _materialize(ev, self.cfg, self.quality_override)


def requests_from_trace(trace: Trace, cfg,
                        quality_override: Optional[str] = None
                        ) -> List[Request]:
    """Fully materialized request list (small traces / tests); prefer
    ``TraceSource`` for serving."""
    return [_materialize(ev, cfg, quality_override)
            for ev in validate_trace(trace).events]


def record_requests(requests: Sequence[Request], cfg,
                    meta: Optional[Dict[str, Any]] = None) -> Trace:
    """Record a request stream as a replayable trace. Token ids cross to
    the host here — one small read per request, in eager pre-serve code
    (never inside the scheduler's event loop)."""
    pairs = []
    for r in requests:
        toks = [int(t) for t in np.asarray(r.prompt["tokens"][0])]
        if cfg.family in ("vlm", "audio") and \
                getattr(r, "modal_seed", None) is None:
            raise ValueError(
                f"rid {r.rid}: cannot record a {cfg.family!r} request "
                "without a modal_seed (non-token leaves are regenerated, "
                "not serialized)")
        pairs.append((r, toks))
    return from_requests(pairs, vocab_size=cfg.vocab_size,
                         family=cfg.family,
                         meta=meta or {"source": "recorded"})


# ------------------------------------------------------------ report joiner
def flatten_report(report: Dict[str, Any]) -> Dict[str, float]:
    """One serve report -> flat scalar metrics row: the total write
    ledger, latency/queue aggregates over the per-request entries, and
    whichever optional ledgers (lifetime, wear, prefix) the run carried."""
    reqs = list(report["requests"].values())
    lat = sorted(r["latency_steps"] for r in reqs)
    row: Dict[str, float] = {
        "requests": float(len(reqs)),
        "clock_steps": float(report["clock_steps"]),
        "decode_steps": float(report["decode_steps"]),
        "bursts": float(report["bursts"]),
        "energy_pj": report["total"]["energy_pj"],
        "energy_pj_per_step": (report["total"]["energy_pj"]
                               / max(1, report["clock_steps"])),
        "write_skip_rate": report["total"]["write_skip_rate"],
        "ber_realized": report["total"]["ber_realized"],
        "mean_latency_steps": sum(lat) / len(lat),
        "p95_latency_steps": float(lat[min(len(lat) - 1,
                                           int(0.95 * len(lat)))]),
        "mean_queue_steps": (sum(r["queue_steps"] for r in reqs)
                             / len(reqs)),
        "peak_occupancy": float(report["pool"]["peak_occupancy"]),
    }
    if "lifetime" in report:
        lt = report["lifetime"]
        row.update({
            "lifetime_energy_pj": lt["lifetime_energy_pj"],
            "scrub_energy_pj": lt["scrub_energy_pj"],
            "retention_flips": float(lt["retention_flips"]),
            "residual_decayed_bits": float(lt["residual_decayed_bits"]),
            "scrub_passes": float(lt["scrub_passes"]),
        })
    if "wear" in report:
        w = report["wear"]
        row.update({
            "max_group_wear": float(w["max_group_wear"]),
            "worn_groups": float(w["worn_groups"]),
            "rotations": float(w["rotations"]),
            "remap_energy_pj": w["remap_energy_pj"],
        })
    if "prefix" in report:
        p = report["prefix"]
        row.update({
            "prefix_hit_rate": p["hit_rate"],
            "linked_admissions": float(p["linked_admissions"]),
            "linked_cols": float(p["linked_cols"]),
            "prefix_net_saved_pj": p["net_energy_saved_pj"],
        })
    if "telemetry" in report:
        t = report["telemetry"]
        row.update({
            "telemetry_events": float(t["events"]),
            "telemetry_spans": float(t["spans"]),
            "telemetry_drains_per_event": t["drains_per_event"],
        })
    return row


def join_reports(entries: Sequence[Dict[str, Any]]) -> Dict[str, Any]:
    """Merge per-(mix, arm) serve reports into one frontier table.

    ``entries`` rows carry {mix, name, pressure, arm, report}; the joined
    table is {"columns": [...], "rows": [...]} with every row flattened
    to scalars — one table a human or the BENCH json can scan across the
    whole ramp × knob grid."""
    rows = []
    for e in entries:
        row = {"mix": e["mix"], "name": e["name"],
               "pressure": round(float(e["pressure"]), 4),
               "arm": e["arm"]}
        row.update(flatten_report(e["report"]))
        rows.append(row)
    columns: List[str] = []
    for r in rows:
        for k in r:
            if k not in columns:
                columns.append(k)
    return {"columns": columns, "rows": rows}
