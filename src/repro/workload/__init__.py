"""repro.workload — trace-driven workload harness for mixed-traffic serving.

The serving stack's policy knobs (extent floors, scrub, wear rotation,
prefix cache, soft-error hardening) were all benchmarked against ONE
synthetic single-distribution arrival stream. This package turns that one
operating point into a scenario-diverse frontier:

  * ``trace``      — a versioned, replayable JSONL trace format (arrival
                     step, prompt tokens, decode length, quality hint,
                     session id, shared-prefix group) with schema
                     validation and bit-exact round-tripping;
  * ``generators`` — deterministic generators for production traffic
                     shapes (steady, diurnal, bursty two-state, heavy-tail
                     contexts, chat-vs-batch mixes, shared-system-prompt
                     floods), seeded through the ``workload-event`` RNG
                     stream so a (preset, seed) pair IS the trace;
  * ``pressure``   — the KV-write-pressure score (admissions × prompt
                     length ÷ slot dwell) that orders generated mixes into
                     a monotone mix1→mixN ramp, ordering asserted;
  * ``replay``     — the trace-iterator arrival source feeding traces into
                     ``serve/scheduler.py`` (lazy prompt materialization,
                     one-sync-per-event discipline preserved), the stream
                     recorder that makes ANY run replayable, and the
                     per-mix report joiner for frontier tables.
"""
from repro.workload.generators import (PRESETS, make_workload)  # noqa: F401
from repro.workload.pressure import (assert_monotone,  # noqa: F401
                                     build_ramp, pressure_score)
from repro.workload.replay import (TraceSource, join_reports,  # noqa: F401
                                   record_requests, requests_from_trace)
from repro.workload.trace import (TRACE_VERSION, Trace,  # noqa: F401
                                  TraceEvent, load_trace, save_trace,
                                  validate_trace)
