"""Versioned, replayable serving-trace format (JSONL).

A trace is the full description of one arrival stream: per request the
arrival step, the explicit prompt token ids (stored verbatim so replay is
bit-exact regardless of which generator produced them), the decode budget,
the optional quality hint / application id, a session id, and an optional
shared-prefix group. Non-token prompt modalities (VLM image embeddings,
audio frames) are not serialized — they are regenerated at replay time
from the recorded ``modal_seed`` with the same recipe the synthetic stream
used, which keeps trace files small while preserving bit-exact replay.

File layout: line 1 is the header object (format marker, version, vocab
bound, provenance metadata); every following line is one event. Events
must be sorted by (arrival, rid) — the scheduler's admission order — and
``validate_trace`` enforces that plus the per-field schema, so a loaded
trace is replayable as-is.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple

#: bump when the event schema changes; loaders reject unknown versions.
TRACE_VERSION = 1

FORMAT_MARKER = "repro.workload.trace"

_QUALITIES = (None, "low", "mid", "high", "exact")


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One request of an arrival stream. ``tokens`` are the explicit
    prompt ids; ``arrival`` is in decode steps (the serving clock);
    ``prefix_group`` marks requests sharing a common prompt head (None =
    no declared sharing); ``modal_seed`` regenerates non-token prompt
    leaves for multimodal families."""
    rid: int
    arrival: int
    tokens: Tuple[int, ...]
    new_tokens: int
    quality: Optional[str] = None
    app_id: Optional[str] = None
    session: Optional[int] = None
    prefix_group: Optional[int] = None
    modal_seed: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(self, "tokens", tuple(int(t)
                                                 for t in self.tokens))

    def to_json(self) -> Dict[str, Any]:
        d = {"rid": self.rid, "arrival": self.arrival,
             "tokens": list(self.tokens), "new_tokens": self.new_tokens}
        for k in ("quality", "app_id", "session", "prefix_group",
                  "modal_seed"):
            v = getattr(self, k)
            if v is not None:
                d[k] = v
        return d

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "TraceEvent":
        return cls(rid=int(d["rid"]), arrival=int(d["arrival"]),
                   tokens=tuple(int(t) for t in d["tokens"]),
                   new_tokens=int(d["new_tokens"]),
                   quality=d.get("quality"), app_id=d.get("app_id"),
                   session=d.get("session"),
                   prefix_group=d.get("prefix_group"),
                   modal_seed=d.get("modal_seed"))


@dataclasses.dataclass(frozen=True)
class Trace:
    """An arrival stream plus its provenance header. ``vocab_size`` bounds
    every token id (0 disables the bound check — hand-written traces);
    ``meta`` records how the trace came to be (preset name, seed,
    generator params) purely for reporting."""
    events: Tuple[TraceEvent, ...]
    vocab_size: int = 0
    family: str = "dense"
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    version: int = TRACE_VERSION

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))

    def __len__(self) -> int:
        return len(self.events)

    def header(self) -> Dict[str, Any]:
        return {"format": FORMAT_MARKER, "version": self.version,
                "vocab_size": self.vocab_size, "family": self.family,
                "meta": self.meta}

    def max_seq(self) -> int:
        """The slot ring length this stream needs (longest prompt+decode
        span over the stream)."""
        return max(len(e.tokens) + e.new_tokens for e in self.events)

    def max_new_tokens(self) -> int:
        return max(e.new_tokens for e in self.events)


def validate_trace(trace: Trace) -> Trace:
    """Schema validation; returns the trace so callers can chain it.

    Raises ``ValueError`` on any violation: unsupported version, empty
    stream, duplicate rids, unsorted or negative arrivals (the scheduler's
    arrival queue pops in (arrival, rid) order — an unsorted trace would
    replay in a different admission order than it records), empty prompts,
    out-of-vocab tokens, non-positive decode budgets, unknown quality
    levels."""
    if trace.version != TRACE_VERSION:
        raise ValueError(f"unsupported trace version {trace.version} "
                         f"(this reader speaks {TRACE_VERSION})")
    if not trace.events:
        raise ValueError("empty trace")
    seen_rids = set()
    prev = None
    for e in trace.events:
        if e.rid in seen_rids:
            raise ValueError(f"duplicate rid {e.rid}")
        seen_rids.add(e.rid)
        if e.arrival < 0:
            raise ValueError(f"rid {e.rid}: negative arrival {e.arrival}")
        if prev is not None and (e.arrival, e.rid) < prev:
            raise ValueError(
                f"rid {e.rid}: events not sorted by (arrival, rid) — "
                "replay admission order would diverge from the recording")
        prev = (e.arrival, e.rid)
        if not e.tokens:
            raise ValueError(f"rid {e.rid}: empty prompt")
        if trace.vocab_size > 0:
            bad = [t for t in e.tokens
                   if not 0 <= t < trace.vocab_size]
            if bad:
                raise ValueError(f"rid {e.rid}: token(s) {bad[:3]} outside "
                                 f"vocab [0, {trace.vocab_size})")
        if e.new_tokens < 1:
            raise ValueError(f"rid {e.rid}: new_tokens {e.new_tokens} < 1")
        if e.quality not in _QUALITIES:
            raise ValueError(f"rid {e.rid}: unknown quality "
                             f"{e.quality!r} (one of {_QUALITIES})")
    return trace


# --------------------------------------------------------------- JSONL io
def dumps(trace: Trace) -> str:
    """The canonical serialization: header line + one event per line,
    stable key order — identical traces produce identical bytes (the
    cross-process determinism tests compare these strings directly)."""
    lines = [json.dumps(trace.header(), sort_keys=True)]
    lines.extend(json.dumps(e.to_json(), sort_keys=True)
                 for e in trace.events)
    return "\n".join(lines) + "\n"


def loads(text: str) -> Trace:
    lines = [ln for ln in text.splitlines() if ln.strip()]
    if not lines:
        raise ValueError("empty trace file")
    header = json.loads(lines[0])
    if header.get("format") != FORMAT_MARKER:
        raise ValueError(f"not a {FORMAT_MARKER} file "
                         f"(header: {header.get('format')!r})")
    events = [TraceEvent.from_json(json.loads(ln)) for ln in lines[1:]]
    return validate_trace(Trace(
        events=tuple(events), vocab_size=int(header.get("vocab_size", 0)),
        family=header.get("family", "dense"),
        meta=header.get("meta", {}),
        version=int(header.get("version", -1))))


def save_trace(trace: Trace, path) -> Path:
    path = Path(path)
    path.write_text(dumps(validate_trace(trace)))
    return path


def load_trace(path) -> Trace:
    return loads(Path(path).read_text())


def from_requests(requests: Sequence[Any], *, vocab_size: int = 0,
                  family: str = "dense",
                  meta: Optional[Dict[str, Any]] = None) -> Trace:
    """Build a trace from scheduler ``Request`` objects (see
    ``replay.record_requests`` for the public recorder — it handles the
    one host read per request)."""
    events: List[TraceEvent] = []
    for r, toks in requests:
        q = r.quality.name.lower() if r.quality is not None else None
        app = r.app_id if isinstance(r.app_id, (str, int)) else (
            None if r.app_id is None else str(r.app_id))
        events.append(TraceEvent(
            rid=r.rid, arrival=r.arrival, tokens=tuple(toks),
            new_tokens=r.new_tokens, quality=q, app_id=app,
            session=getattr(r, "session", None),
            modal_seed=getattr(r, "modal_seed", None)))
    events.sort(key=lambda e: (e.arrival, e.rid))
    return validate_trace(Trace(events=tuple(events),
                                vocab_size=vocab_size, family=family,
                                meta=meta or {"source": "recorded"}))
