"""Deterministic trace generators for production traffic shapes.

Every preset maps ``(cfg, n, seed, params) -> Trace`` with zero hidden
state: all randomness forks off the workload root key
``PRNGKey(seed)`` through the registered ``workload-event`` sub-stream
(``rng_streams.WORKLOAD_OFFSET + event_index``), so the same (preset,
seed) pair produces the byte-identical trace in any process on any day —
no wall clock, no global RNG, and the rng-stream-hygiene lint rule covers
the fold constants.

Prompt lengths are quantized to ``LEN_QUANTUM`` so a heavy-tailed mix
produces a handful of distinct prompt shapes (each distinct shape is one
compiled prefill executable) instead of one per request.

Presets:

  * ``steady``               — fixed-gap arrivals, fixed shapes (the
                               synthetic default as a trace);
  * ``diurnal``              — arrival gaps swept along one day-curve
                               period (load peaks mid-stream);
  * ``bursty``               — two-state modulated arrivals: an ON state
                               admits back-to-back, OFF goes quiet, with
                               seeded state transitions;
  * ``heavy_tail``           — Pareto-ish context lengths (many short
                               prompts, a fat tail of long ones);
  * ``chat_batch``           — interactive chat (short prompt, short
                               decode, HIGH hint) mixed with batch jobs
                               (long prompt, long decode, LOW hint);
  * ``shared_system_prompt`` — one system prompt shared by the whole
                               stream with unique tails: the prefix-cache
                               × wear adversarial workload (every hit
                               pins the owner's physical rows).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.memory import rng_streams
from repro.workload.trace import Trace, TraceEvent, validate_trace

#: prompt lengths snap to multiples of this (compile-shape hygiene).
LEN_QUANTUM = 4


def _event_key(seed: int, index: int) -> jax.Array:
    """The per-event sub-key: workload root key + the registered
    workload-event stream offset."""
    return jax.random.fold_in(jax.random.PRNGKey(seed),
                              rng_streams.WORKLOAD_OFFSET + index)


def _draw_tokens(key: jax.Array, n: int, vocab: int) -> List[int]:
    return [int(t) for t in
            jax.random.randint(key, (n,), 0, vocab)]


def _uniform(key: jax.Array) -> float:
    return float(jax.random.uniform(key))


def _quantize(n: int, lo: int, hi: int) -> int:
    q = max(lo, min(hi, n))
    return max(LEN_QUANTUM, (q // LEN_QUANTUM) * LEN_QUANTUM)


def _finish(cfg, events: List[TraceEvent], preset: str, seed: int,
            params: Dict[str, Any]) -> Trace:
    events.sort(key=lambda e: (e.arrival, e.rid))
    return validate_trace(Trace(
        events=tuple(events), vocab_size=cfg.vocab_size,
        family=cfg.family,
        meta={"preset": preset, "seed": seed, "params": params}))


# ------------------------------------------------------------------ presets
def steady(cfg, n: int, seed: int, *, prompt_len: int = 8,
           new_tokens: int = 6, arrival_every: int = 4,
           quality: Optional[str] = None,
           app_id: Optional[str] = None) -> Trace:
    events = []
    for i in range(n):
        k = _event_key(seed, i)
        events.append(TraceEvent(
            rid=i, arrival=i * arrival_every,
            tokens=_draw_tokens(k, prompt_len, cfg.vocab_size),
            new_tokens=new_tokens, quality=quality, app_id=app_id,
            session=i))
    return _finish(cfg, events, "steady", seed, dict(
        prompt_len=prompt_len, new_tokens=new_tokens,
        arrival_every=arrival_every))


def diurnal(cfg, n: int, seed: int, *, prompt_len: int = 8,
            new_tokens: int = 6, base_gap: int = 4,
            peak_gap: int = 1) -> Trace:
    """One day-curve period over the stream: gaps shrink from ``base_gap``
    at the edges to ``peak_gap`` mid-stream (deterministic cosine ramp —
    the arrival *process* is the shape here, not the draws)."""
    import math
    events, arrival = [], 0
    for i in range(n):
        k = _event_key(seed, i)
        phase = math.cos(2.0 * math.pi * (i / max(1, n) - 0.5))
        gap = round(peak_gap + (base_gap - peak_gap) * (1 - phase) / 2)
        arrival += max(0, int(gap))
        events.append(TraceEvent(
            rid=i, arrival=arrival,
            tokens=_draw_tokens(k, prompt_len, cfg.vocab_size),
            new_tokens=new_tokens, session=i))
    return _finish(cfg, events, "diurnal", seed, dict(
        prompt_len=prompt_len, new_tokens=new_tokens, base_gap=base_gap,
        peak_gap=peak_gap))


def bursty(cfg, n: int, seed: int, *, prompt_len: int = 12,
           new_tokens: int = 4, quiet_gap: int = 6,
           p_enter_burst: float = 0.4, p_exit_burst: float = 0.3) -> Trace:
    """Two-state modulated arrival process: in the burst state requests
    arrive back-to-back (gap 0), in the quiet state ``quiet_gap`` apart;
    the state chain transitions on seeded per-event draws."""
    events, arrival, in_burst = [], 0, False
    for i in range(n):
        k = _event_key(seed, i)
        k_tok, k_state = jax.random.split(k)
        u = _uniform(k_state)
        in_burst = (u < (1.0 - p_exit_burst) if in_burst
                    else u < p_enter_burst)
        arrival += 0 if in_burst else quiet_gap
        events.append(TraceEvent(
            rid=i, arrival=arrival,
            tokens=_draw_tokens(k_tok, prompt_len, cfg.vocab_size),
            new_tokens=new_tokens, session=i))
    return _finish(cfg, events, "bursty", seed, dict(
        prompt_len=prompt_len, new_tokens=new_tokens, quiet_gap=quiet_gap,
        p_enter_burst=p_enter_burst, p_exit_burst=p_exit_burst))


def heavy_tail(cfg, n: int, seed: int, *, min_len: int = 4,
               max_len: int = 24, alpha: float = 1.2,
               new_tokens: int = 4, arrival_every: int = 2) -> Trace:
    """Long-tail context lengths via the Pareto inverse CDF
    ``min_len * (1-u)^(-1/alpha)``, clamped to [min_len, max_len] and
    quantized — most prompts are short, a fat tail is long (the mix that
    stresses admission write volume)."""
    events = []
    for i in range(n):
        k = _event_key(seed, i)
        k_tok, k_len = jax.random.split(k)
        u = min(_uniform(k_len), 0.999)
        plen = _quantize(int(min_len * (1.0 - u) ** (-1.0 / alpha)),
                         min_len, max_len)
        events.append(TraceEvent(
            rid=i, arrival=i * arrival_every,
            tokens=_draw_tokens(k_tok, plen, cfg.vocab_size),
            new_tokens=new_tokens, session=i))
    return _finish(cfg, events, "heavy_tail", seed, dict(
        min_len=min_len, max_len=max_len, alpha=alpha,
        new_tokens=new_tokens, arrival_every=arrival_every))


def chat_batch(cfg, n: int, seed: int, *, chat_frac: float = 0.5,
               chat_prompt: int = 8, chat_tokens: int = 8,
               batch_prompt: int = 20, batch_tokens: int = 3,
               arrival_every: int = 2) -> Trace:
    """Interactive chat traffic (short prompts, longer decodes, HIGH
    quality hints) interleaved with batch jobs (long prompts, short
    decodes, LOW hints) — the mix where per-request quality floors and
    admission policy actually disagree."""
    events = []
    for i in range(n):
        k = _event_key(seed, i)
        k_tok, k_kind = jax.random.split(k)
        if _uniform(k_kind) < chat_frac:
            plen, nt, app, q = chat_prompt, chat_tokens, "chat", "high"
        else:
            plen, nt, app, q = batch_prompt, batch_tokens, "batch", "low"
        events.append(TraceEvent(
            rid=i, arrival=i * arrival_every,
            tokens=_draw_tokens(k_tok, plen, cfg.vocab_size),
            new_tokens=nt, quality=q, app_id=app, session=i))
    return _finish(cfg, events, "chat_batch", seed, dict(
        chat_frac=chat_frac, chat_prompt=chat_prompt,
        chat_tokens=chat_tokens, batch_prompt=batch_prompt,
        batch_tokens=batch_tokens, arrival_every=arrival_every))


def shared_system_prompt(cfg, n: int, seed: int, *, shared_len: int = 16,
                         tail_len: int = 4, new_tokens: int = 3,
                         arrival_every: int = 1,
                         quality: Optional[str] = "high") -> Trace:
    """The prefix×wear adversarial flood: every request opens with the
    SAME ``shared_len``-token system prompt (drawn once, from event index
    ``n`` so it never collides with a request's own stream) plus a unique
    tail. Under the prefix cache the whole stream links one owner's
    resident columns — wear-once admission booking makes those physical
    rows the hottest, longest-lived rows in the pool, which is exactly
    what the rotate wear policy must migrate before the endurance budget
    goes stuck-at. ``quality="high"`` keeps wear-aware admission in the
    loop (HIGH requests steer by slot wear scores)."""
    shared = _draw_tokens(_event_key(seed, n), shared_len, cfg.vocab_size)
    events = []
    for i in range(n):
        k = _event_key(seed, i)
        tail = _draw_tokens(k, tail_len, cfg.vocab_size)
        events.append(TraceEvent(
            rid=i, arrival=i * arrival_every,
            tokens=tuple(shared) + tuple(tail),
            new_tokens=new_tokens, quality=quality, session=i,
            prefix_group=0))
    return _finish(cfg, events, "shared_system_prompt", seed, dict(
        shared_len=shared_len, tail_len=tail_len, new_tokens=new_tokens,
        arrival_every=arrival_every, quality=quality))


PRESETS: Dict[str, Callable[..., Trace]] = {
    "steady": steady,
    "diurnal": diurnal,
    "bursty": bursty,
    "heavy_tail": heavy_tail,
    "chat_batch": chat_batch,
    "shared_system_prompt": shared_system_prompt,
}


def make_workload(preset: str, cfg, n: int, seed: int = 0,
                  **params) -> Trace:
    """Build a trace from a named preset. Unknown preset names list the
    registry in the error (the launcher surfaces this directly)."""
    try:
        fn = PRESETS[preset]
    except KeyError:
        raise ValueError(f"unknown workload preset {preset!r} "
                         f"(available: {', '.join(sorted(PRESETS))})")
    return fn(cfg, n, seed, **params)
