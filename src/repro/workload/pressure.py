"""KV-write-pressure scoring and the monotone mix ramp.

The Kill-Llama harness graded SPEC mixes mix1→mix7 by rising last-level-
cache MPKI — a *monotone pressure axis* — and re-ran every cache policy
across the whole ramp so a policy's win had to survive the full pressure
spectrum. Our analogue for an STT-RAM-backed KV cache is **KV write
pressure**: how many prompt tokens per serving step the stream admits,
amortized over how long each admission's columns stay resident —

    pressure = (admissions / makespan) × mean_prompt_len / mean_dwell

(admission rate × prompt length ÷ slot dwell). High pressure means the
pool churns fresh KV writes every step (admission-dominated, the regime
where write energy, wear, and prefix reuse all concentrate); low pressure
means long-dwelling decodes amortize each admission.

``build_ramp`` generates one mix per preset family with parameters spread
across that axis, then **orders the mixes by their measured score and
asserts strict monotonicity** — the ramp is sorted evidence, not a naming
convention.
"""
from __future__ import annotations

from typing import Any, Dict, List, Sequence

from repro.workload import generators
from repro.workload.trace import Trace


def pressure_score(trace: Trace) -> float:
    """Admissions × mean prompt length ÷ mean slot dwell, per step of the
    stream's makespan. Pure trace metadata — no serving run needed."""
    n = len(trace.events)
    first = trace.events[0].arrival
    last = trace.events[-1].arrival
    makespan = max(1, last - first + 1)
    mean_prompt = sum(len(e.tokens) for e in trace.events) / n
    mean_dwell = sum(e.new_tokens for e in trace.events) / n
    return (n / makespan) * mean_prompt / max(1.0, mean_dwell)


def assert_monotone(scores: Sequence[float]) -> None:
    """Strictly increasing, or the ramp is not a pressure axis."""
    for i, (a, b) in enumerate(zip(scores, scores[1:])):
        assert a < b, (
            f"pressure ramp not strictly monotone at mix{i + 1}->"
            f"mix{i + 2}: {a:.4f} >= {b:.4f}")


def order_ramp(mixes: Dict[str, Trace]) -> List[Dict[str, Any]]:
    """Order named mixes by measured KV-write pressure into mix1→mixN,
    asserting strict monotonicity. Returns [{mix, name, trace, pressure}]
    with ``mix`` the 1-based rank."""
    scored = sorted(((pressure_score(t), name, t)
                     for name, t in mixes.items()), key=lambda x: x[0])
    assert_monotone([s for s, _, _ in scored])
    return [{"mix": i + 1, "name": name, "trace": t, "pressure": s}
            for i, (s, name, t) in enumerate(scored)]


def build_ramp(cfg, seed: int = 0, n: int = 6) -> List[Dict[str, Any]]:
    """The default mixed-traffic ramp: one mix per preset family with
    parameters spread along the pressure axis — sparse steady traffic at
    the bottom, a shared-system-prompt admission flood at the top. The
    ordering is measured and asserted, never assumed."""
    mixes = {
        # long gaps, short prompts, long dwells: admission-starved
        "steady_sparse": generators.steady(
            cfg, n, seed, prompt_len=8, new_tokens=8, arrival_every=8),
        # day-curve load with a mid-stream peak
        "diurnal": generators.diurnal(
            cfg, n, seed, prompt_len=8, new_tokens=6, base_gap=6,
            peak_gap=2),
        # chat/batch disagreement: mixed shapes, mixed dwells
        "chat_batch": generators.chat_batch(
            cfg, n, seed, arrival_every=3),
        # fat-tail contexts: admission write volume concentrates
        "heavy_tail": generators.heavy_tail(
            cfg, n, seed, min_len=4, max_len=24, new_tokens=4,
            arrival_every=2),
        # two-state spikes: back-to-back admissions in the ON state
        "bursty_spikes": generators.bursty(
            cfg, n, seed, prompt_len=16, new_tokens=3, quiet_gap=4),
        # the flood: everyone arrives nearly at once with a big shared
        # prompt and barely decodes — peak admissions × prompt ÷ dwell
        "shared_prefix_flood": generators.shared_system_prompt(
            cfg, n, seed, shared_len=16, tail_len=4, new_tokens=2,
            arrival_every=1),
    }
    return order_ramp(mixes)
