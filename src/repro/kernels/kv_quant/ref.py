"""Pure-jnp oracle for the kv_quant kernel (same RNG, same semantics)."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.extent_write.kernel import uniform_bits
from repro.kernels.kv_quant.kernel import QMAX


def kv_quant_ref(x: jax.Array, seed: jax.Array, thr: jax.Array,
                 block: Tuple[int, int]) -> Tuple[jax.Array, jax.Array,
                                                  jax.Array]:
    R, C = x.shape
    br, bc = block
    gr, gc = R // br, C // bc
    xf = x.astype(jnp.float32)
    blocks = xf.reshape(gr, br, gc, bc).transpose(0, 2, 1, 3)  # (gr,gc,br,bc)
    absmax = jnp.max(jnp.abs(blocks), axis=(2, 3))
    scales = jnp.maximum(absmax, 1e-12) / QMAX                  # (gr, gc)
    q = jnp.clip(jnp.round(blocks / scales[:, :, None, None]), -QMAX,
                 QMAX).astype(jnp.int32)

    elem = (jnp.arange(R, dtype=jnp.uint32)[:, None] * jnp.uint32(C)
            + jnp.arange(C, dtype=jnp.uint32)[None, :])
    elem_b = elem.reshape(gr, br, gc, bc).transpose(0, 2, 1, 3)

    qu = q.astype(jnp.uint32) & jnp.uint32(0xFF)
    bits = jnp.arange(8, dtype=jnp.uint32)
    mask = jnp.uint32(1) << bits
    is_set = (qu[..., None] & mask) != 0
    u = jnp.stack([uniform_bits(seed[0], elem_b, b) for b in range(8)],
                  axis=-1)
    fail = is_set & (u < thr)
    fail_mask = jnp.sum(jnp.where(fail, mask, jnp.uint32(0)), axis=-1,
                        dtype=jnp.uint32)
    stored_u = qu ^ fail_mask
    stored = ((stored_u.astype(jnp.int32) ^ 0x80) - 0x80).astype(jnp.int8)
    stored = stored.transpose(0, 2, 1, 3).reshape(R, C)
    errors = jnp.sum(fail, axis=(2, 3, 4), dtype=jnp.int32)
    return stored, scales, errors
