"""Pallas-TPU kernel: fused int8 KV quantize + EXTENT approximate store.

The §Perf llama4-decode iteration-3 lever made concrete: KV entries are
stored as int8 payloads (per-(row-block) symmetric scale kept EXACT in a
side tensor) and the int8 payload is written through the EXTENT LOW/MID
driver — quantization *is* the bit-plane priority map taken to its
conclusion (drop 8 mantissa bits entirely, approximate the rest).

Fusion: bf16/f32 KV values stream HBM->VMEM once; absmax reduction,
scaling, rounding, the stochastic write-failure draw (same murmur3 counter
RNG as extent_write) and the int8 pack all happen in VREGs; HBM sees only
the int8 payload + per-block scales. Unfused, the quantize and the
approximate-store each round-trip the tensor.

Layout: input (R, C) float lanes; per-row-block scale (grid_r, grid_c).
Dequant lives in ops.py (one multiply at read time — decode attention
consumes int8 K/V against f32 scales).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.extent_write.kernel import uniform_bits

DEFAULT_BLOCK = (256, 512)
QMAX = 127.0


def _kernel(x_ref, seed_ref, thr_ref, stored_ref, scale_ref, errors_ref,
            *, block: Tuple[int, int], cols_total: int):
    r, c = pl.program_id(0), pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)
    seed = seed_ref[0]

    absmax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(absmax, 1e-12) / QMAX
    q = jnp.clip(jnp.round(x / scale), -QMAX, QMAX).astype(jnp.int32)

    # EXTENT stochastic store of the int8 payload: erased-row write model
    # (old = 0), so only set bits can fail (0->1 weak direction). thr is the
    # (8,) per-bit failure threshold vector for the chosen driver level.
    rows = jax.lax.broadcasted_iota(jnp.uint32, block, 0) + jnp.uint32(
        r * block[0])
    cols = jax.lax.broadcasted_iota(jnp.uint32, block, 1) + jnp.uint32(
        c * block[1])
    elem = rows * jnp.uint32(cols_total) + cols

    qu = q.astype(jnp.uint32) & jnp.uint32(0xFF)  # two's-complement byte
    fail_acc = jnp.zeros(block, jnp.uint32)
    nerr = jnp.zeros(block, jnp.uint32)
    one = jnp.uint32(1)
    for b in range(8):
        bitmask = one << b
        is_set = (qu & bitmask) != 0
        u = uniform_bits(seed, elem, b)
        fail = is_set & (u < thr_ref[b])
        fail_acc = fail_acc | jnp.where(fail, bitmask, jnp.uint32(0))
        nerr = nerr + fail.astype(jnp.uint32)

    stored_u = qu ^ fail_acc
    # sign-extend back to int32 then truncate to int8 semantics
    stored = (stored_u.astype(jnp.int32) ^ 0x80) - 0x80
    stored_ref[...] = stored.astype(jnp.int8)
    scale_ref[0, 0] = scale
    errors_ref[0, 0] = jnp.sum(nerr.astype(jnp.int32))


def kv_quant_kernel(
    x: jax.Array,           # (R, C) f32/bf16 lanes, R % block[0] == 0
    seed: jax.Array,        # (1,) uint32
    thr: jax.Array,         # (8,) uint32 per-bit failure thresholds
    *,
    block: Tuple[int, int] = DEFAULT_BLOCK,
    interpret: bool = True,
):
    """Returns (q_int8 (R, C), scales (gr, gc) f32, errors (gr, gc) i32)."""
    R, C = x.shape
    assert R % block[0] == 0 and C % block[1] == 0, (x.shape, block)
    grid = (R // block[0], C // block[1])
    return pl.pallas_call(
        functools.partial(_kernel, block=block, cols_total=C),
        grid=grid,
        in_specs=[
            pl.BlockSpec(block, lambda r, c: (r, c)),
            pl.BlockSpec((1,), lambda r, c: (0,)),
            pl.BlockSpec((8,), lambda r, c: (0,)),
        ],
        out_specs=[
            pl.BlockSpec(block, lambda r, c: (r, c)),
            pl.BlockSpec((1, 1), lambda r, c: (r, c)),
            pl.BlockSpec((1, 1), lambda r, c: (r, c)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, C), jnp.int8),
            jax.ShapeDtypeStruct(grid, jnp.float32),
            jax.ShapeDtypeStruct(grid, jnp.int32),
        ],
        interpret=interpret,
    )(x, seed, thr)
