from repro.kernels.kv_quant.ops import kv_dequant, kv_quant_store  # noqa: F401
from repro.kernels.kv_quant.kernel import kv_quant_kernel  # noqa: F401
from repro.kernels.kv_quant.ref import kv_quant_ref  # noqa: F401
