"""jit'd wrapper for the fused int8-KV quantize + EXTENT store."""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import write_driver
from repro.core.priority import Priority
from repro.kernels.kv_quant import kernel as K
from repro.kernels.kv_quant import ref as R


@functools.lru_cache(maxsize=8)
def _thresholds(level: Priority) -> jax.Array:
    """(8,) per-bit failure thresholds for the int8 payload: the top bit
    (sign) rides the next level up — a sign flip is the int8 'exponent'."""
    table = write_driver.level_table()
    lvl = int(Priority.coerce(level))
    codes = np.full((8,), lvl, np.int32)
    codes[7] = min(lvl + 1, int(Priority.EXACT))  # protect the sign bit
    wer = np.asarray(table["wer01"])[codes]
    thr = (np.clip(wer, 0.0, 1.0) * 2**32).astype(np.uint64)
    return jnp.asarray(thr.clip(0, 2**32 - 1).astype(np.uint32))


def kv_quant_store(
    key: jax.Array,
    kv: jax.Array,                       # any shape, f32/bf16
    *,
    level: Priority = Priority.MID,
    block: Tuple[int, int] = (64, 128),
    use_kernel: bool = True,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array, Dict[str, jax.Array]]:
    """Quantize + approximately store a KV tensor.

    Returns (q_int8 (same shape), scales (gr, gc), stats). Dequantize with
    ``kv_dequant``. Padding rows quantize to 0 and cannot fail (0 bits set).

    Default level is MID, not LOW: every int8 payload bit is significant
    (quantization already dropped the LOW-tolerance mantissa tail), so MID
    keeps the stochastic-write error at ~the quantization-noise floor
    (rel-err 1.3% vs 1.0% pure-quant; LOW would be 17%).
    """
    thr = _thresholds(Priority.coerce(level))
    seed = jax.random.bits(key, (1,), jnp.uint32)
    flat = kv.reshape(-1)
    n = flat.size
    bc = block[0] * block[1]
    pad = (-n) % bc
    xp = jnp.concatenate([flat.astype(jnp.float32),
                          jnp.zeros((pad,), jnp.float32)])
    rows = xp.size // block[1]
    x2 = xp.reshape(rows, block[1])
    blk = (min(block[0], rows), block[1])
    if use_kernel:
        q2, scales, errors = K.kv_quant_kernel(x2, seed, thr, block=blk,
                                               interpret=interpret)
    else:
        q2, scales, errors = R.kv_quant_ref(x2, seed, thr, blk)
    q = q2.reshape(-1)[:n].reshape(kv.shape)
    stats = {"errors": jnp.sum(errors),
             "bytes_stored": jnp.asarray(n, jnp.int32),
             "bytes_saved": jnp.asarray(
                 n * (kv.dtype.itemsize - 1), jnp.int32)}
    return q, scales, stats


def kv_dequant(q: jax.Array, scales: jax.Array,
               block: Tuple[int, int] = (64, 128),
               out_dtype=jnp.bfloat16) -> jax.Array:
    """Inverse of kv_quant_store's layout: broadcast per-block scales."""
    shape = q.shape
    flat = q.reshape(-1)
    n = flat.size
    bc = block[0] * block[1]
    pad = (-n) % bc
    qp = jnp.concatenate([flat, jnp.zeros((pad,), q.dtype)])
    rows = qp.size // block[1]
    blk_r = min(block[0], rows)
    q2 = qp.reshape(rows // blk_r, blk_r, -1, block[1])
    out = q2.astype(jnp.float32) * scales[:, None, :, None]
    return out.reshape(-1)[:n].reshape(shape).astype(out_dtype)
