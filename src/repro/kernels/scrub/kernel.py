"""Pallas-TPU kernel for the STT-RAM scrub (corrective re-write) pass.

A scrub pass walks stored data whose bits may have decayed since the last
write (retention failures accumulate in a per-leaf decay *mask* — bit i set
means stored bit i currently differs from the value the write intended) and
re-writes exactly those bits: read + ECC-correct + write-back, the standard
MRAM scrubbing loop. Fused, in one HBM pass over (stored, mask):

    corrected = stored XOR mask  ->  stochastic re-write of the mask bits
    -> scrubbed word + RESIDUAL mask (re-writes that failed stay decayed and
       are retried on the next pass) + per-block energy/flip/error sums.

The re-write obeys the same EXTENT driver semantics as the write path: each
corrected bit pays the level's per-direction flip energy and fails with the
level's direction WER (a failed correction leaves the decayed value — the
cell kept its wrong state). Words with an all-zero mask are untouched at
zero energy, the CMP redundant-write elimination applied to scrubbing.

RNG/layout contract: identical to ``kernels/extent_write`` — counter hash of
(seed, FLAT lane index, bit plane), so results are invariant to how ops.py
partitions the lane vector into a grid, and ``ref.py`` reproduces the kernel
bit-exactly in pure jnp.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.extent_write.kernel import DEFAULT_BLOCK, uniform_bits


def _kernel(
    stored_ref, mask_ref, seed_ref, thr01_ref, thr10_ref, e01_ref, e10_ref,
    scrubbed_ref, residual_ref, energy_ref, flips01_ref, flips10_ref,
    errors_ref, *, nbits: int, block: Tuple[int, int], cols_total: int,
):
    r, c = pl.program_id(0), pl.program_id(1)
    stored = stored_ref[...]
    mask = mask_ref[...]
    seed = seed_ref[0]

    # global flat lane index of each lane in this block (layout-invariant)
    row0 = r * block[0]
    col0 = c * block[1]
    rows = jax.lax.broadcasted_iota(jnp.uint32, block, 0) + jnp.uint32(row0)
    cols = jax.lax.broadcasted_iota(jnp.uint32, block, 1) + jnp.uint32(col0)
    elem = rows * jnp.uint32(cols_total) + cols

    corrected = stored ^ mask
    one = jnp.uint32(1)

    fail_acc = jnp.zeros(block, jnp.uint32)
    energy = jnp.zeros(block, jnp.float32)
    n01 = jnp.zeros(block, jnp.uint32)
    n10 = jnp.zeros(block, jnp.uint32)
    nerr = jnp.zeros(block, jnp.uint32)

    for b in range(nbits):  # static unroll: nbits is 16 or 32
        bitmask = one << b
        rewrite = (mask & bitmask) != 0                 # decayed -> re-write
        to_ap = rewrite & ((corrected & bitmask) != 0)  # correcting to 1
        u = uniform_bits(seed, elem, b)
        thr = jnp.where(to_ap, thr01_ref[b], thr10_ref[b])
        fail = rewrite & (u < thr)
        fail_acc = fail_acc | jnp.where(fail, bitmask, jnp.uint32(0))
        e_bit = jnp.where(to_ap, e01_ref[b], e10_ref[b])
        energy = energy + jnp.where(rewrite, e_bit, 0.0)
        n01 = n01 + to_ap.astype(jnp.uint32)
        n10 = n10 + (rewrite & ~to_ap).astype(jnp.uint32)
        nerr = nerr + fail.astype(jnp.uint32)

    scrubbed_ref[...] = corrected ^ fail_acc  # failed bits stay decayed
    residual_ref[...] = fail_acc              # retried on the next pass
    energy_ref[0, 0] = jnp.sum(energy)
    flips01_ref[0, 0] = jnp.sum(n01.astype(jnp.int32))
    flips10_ref[0, 0] = jnp.sum(n10.astype(jnp.int32))
    errors_ref[0, 0] = jnp.sum(nerr.astype(jnp.int32))


def scrub_kernel(
    stored_u32: jax.Array,   # (R, C) uint32 lanes, R % block[0] == 0 etc.
    mask_u32: jax.Array,     # (R, C) uint32 decayed-bit mask
    seed: jax.Array,         # (1,) uint32
    thr01: jax.Array,        # (nbits,) uint32 failure thresholds (wer * 2^32)
    thr10: jax.Array,
    e01: jax.Array,          # (nbits,) f32 per-flip energies (pJ)
    e10: jax.Array,
    *,
    nbits: int,
    block: Tuple[int, int] = DEFAULT_BLOCK,
    interpret: bool = True,  # CPU container: validate via interpreter
):
    """Returns (scrubbed (R,C) u32, residual_mask (R,C) u32, energy (gr,gc)
    f32, flips01, flips10, errors (gr,gc) i32). Stats are per-block sums."""
    R, C = stored_u32.shape
    assert R % block[0] == 0 and C % block[1] == 0, (stored_u32.shape, block)
    grid = (R // block[0], C // block[1])

    vec_spec = pl.BlockSpec((nbits,), lambda r, c: (0,))
    stat_spec = pl.BlockSpec((1, 1), lambda r, c: (r, c))
    data_spec = pl.BlockSpec(block, lambda r, c: (r, c))

    return pl.pallas_call(
        functools.partial(_kernel, nbits=nbits, block=block, cols_total=C),
        grid=grid,
        in_specs=[
            data_spec, data_spec,
            pl.BlockSpec((1,), lambda r, c: (0,)),   # seed
            vec_spec, vec_spec, vec_spec, vec_spec,
        ],
        out_specs=[
            data_spec, data_spec, stat_spec, stat_spec, stat_spec, stat_spec,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, C), jnp.uint32),
            jax.ShapeDtypeStruct((R, C), jnp.uint32),
            jax.ShapeDtypeStruct(grid, jnp.float32),
            jax.ShapeDtypeStruct(grid, jnp.int32),
            jax.ShapeDtypeStruct(grid, jnp.int32),
            jax.ShapeDtypeStruct(grid, jnp.int32),
        ],
        interpret=interpret,
    )(stored_u32, mask_u32, seed, thr01, thr10, e01, e10)
