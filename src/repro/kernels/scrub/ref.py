"""Pure-jnp oracle for the scrub Pallas kernel.

Identical semantics (same murmur3 counter RNG over flat lane indices, same
bit algebra, same stats) with plain jnp ops over the unpacked
(lanes x nbits) tensor — the reference every scrub-kernel test asserts
against bit-exactly.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.extent_write.ref import _uniform_bits_all


def scrub_ref(
    stored_u32: jax.Array,   # (R, C) uint32 lanes
    mask_u32: jax.Array,     # (R, C) uint32 decayed-bit mask
    seed: jax.Array,         # (1,) uint32
    thr01: jax.Array,        # (nbits,) uint32
    thr10: jax.Array,
    e01: jax.Array,          # (nbits,) f32
    e10: jax.Array,
    *,
    nbits: int,
) -> Tuple[jax.Array, jax.Array, Dict[str, jax.Array]]:
    """Returns (scrubbed, residual_mask, stats) — see kernel.py for the
    corrective-re-write semantics."""
    R, C = stored_u32.shape
    elem = (jnp.arange(R, dtype=jnp.uint32)[:, None] * jnp.uint32(C)
            + jnp.arange(C, dtype=jnp.uint32)[None, :])

    bits = jnp.arange(nbits, dtype=jnp.uint32)
    bitmask = (jnp.uint32(1) << bits)                       # (nbits,)
    corrected = stored_u32 ^ mask_u32
    rewrite = (mask_u32[..., None] & bitmask) != 0          # (R,C,nbits)
    to_ap = rewrite & ((corrected[..., None] & bitmask) != 0)

    u = _uniform_bits_all(seed[0], elem, nbits)
    thr = jnp.where(to_ap, thr01, thr10)
    fail = rewrite & (u < thr)

    fail_mask = jnp.sum(jnp.where(fail, bitmask, jnp.uint32(0)), axis=-1,
                        dtype=jnp.uint32)
    scrubbed = corrected ^ fail_mask

    e_bit = jnp.where(to_ap, e01, e10)
    stats = {
        "energy_pj": jnp.sum(jnp.where(rewrite, e_bit, 0.0),
                             dtype=jnp.float32),
        "flips01": jnp.sum(to_ap, dtype=jnp.int32),
        "flips10": jnp.sum(rewrite & ~to_ap, dtype=jnp.int32),
        "errors": jnp.sum(fail, dtype=jnp.int32),
    }
    return scrubbed, fail_mask, stats
