"""Public jit'd wrapper for the scrub kernel.

Mirrors ``kernels/extent_write/ops.py``: dtype bitcasting into uint32 lanes
(shared ``_to_lanes``/``_from_lanes`` plumbing), right-sized grids with
row-block padding only, threshold/energy vector operands, per-block stat
reduction, and auto-interpret on CPU hosts (``interpret=None``).

The decay *mask* rides in element space (``uint_type(data.dtype)``, same
shape as the data — maintained by ``repro.reliability.lifetime``) and is
lane-packed here exactly like the data, so the kernel sees matching lanes.

This module is kernel-internal plumbing: everything outside
``repro/kernels`` and ``repro/memory`` reaches scrubbing through the
backend registry (``Backend.leaf_scrub``) or ``repro.reliability.scrub``.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.extent_write import kernel as WK
from repro.kernels.extent_write.ops import _from_lanes, _to_lanes
from repro.kernels.scrub import kernel as K
from repro.kernels.scrub import ref as R

from repro.core.priority import uint_type


def scrub_write(
    key: jax.Array,
    stored: jax.Array,
    mask: jax.Array,
    *,
    vectors: Tuple[jax.Array, jax.Array, jax.Array, jax.Array],
    block: Tuple[int, int] = WK.DEFAULT_BLOCK,
    use_kernel: bool = True,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array, Dict[str, jax.Array]]:
    """Corrective re-write of the decayed bits of ``stored`` (see kernel.py).

    ``mask`` is the element-space decayed-bit mask (``uint_type`` of the
    stored dtype, same shape). ``vectors`` is the lane-tiled
    (thr01, thr10, e01, e10) quadruple from
    ``kernels.extent_write.ops.level_vectors`` — the same driver operands
    the write path uses, so a scrub pays write-path prices.

    Returns (scrubbed, residual_mask, stats{energy_pj, flips01, flips10,
    errors, bits_written, bits_total}); ``residual_mask`` holds the
    corrections that FAILED (still-decayed bits, retried next pass);
    ``bits_total`` counts the scanned element bits, never the lane padding.
    """
    assert stored.shape == mask.shape, (stored.shape, mask.shape)
    assert jnp.dtype(mask.dtype) == jnp.dtype(uint_type(stored.dtype)), (
        mask.dtype, stored.dtype)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    thr01, thr10, e01, e10 = vectors
    return _scrub_jit(key, stored, mask, thr01, thr10, e01, e10,
                      block=block, use_kernel=use_kernel,
                      interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block", "use_kernel",
                                             "interpret"))
def _scrub_jit(
    key, stored, mask, thr01, thr10, e01, e10, *,
    block: Tuple[int, int], use_kernel: bool, interpret: bool,
) -> Tuple[jax.Array, jax.Array, Dict[str, jax.Array]]:
    nbits = int(thr01.shape[0])
    seed = jax.random.bits(key, (1,), jnp.uint32)

    stored_u, _ = _to_lanes(stored)
    mask_u, _ = _to_lanes(mask)
    n_lanes = stored_u.size

    # same right-sized grid policy as extent_write: the counter RNG hashes
    # the FLAT lane index, so any (rows, cols) partition is bit-identical —
    # only rows are padded, to the row-block (never a full 256x512 pad).
    if use_kernel:
        cols = block[1]
        rows_used = max(1, -(-n_lanes // cols))
        block_r = min(block[0], rows_used)
        rows = -(-rows_used // block_r) * block_r
    else:
        cols = n_lanes if n_lanes else 1
        rows = 1
    pad = rows * cols - n_lanes
    # padding lanes: mask == 0 -> no re-writes, no energy, no failures
    stored2 = jnp.concatenate(
        [stored_u, jnp.zeros((pad,), jnp.uint32)]).reshape(rows, cols)
    mask2 = jnp.concatenate(
        [mask_u, jnp.zeros((pad,), jnp.uint32)]).reshape(rows, cols)

    if use_kernel:
        scrubbed2, residual2, energy, f01, f10, err = K.scrub_kernel(
            stored2, mask2, seed, thr01, thr10, e01, e10,
            nbits=nbits, block=(min(block[0], rows), cols),
            interpret=interpret)
        stats = {"energy_pj": jnp.sum(energy),
                 "flips01": jnp.sum(f01), "flips10": jnp.sum(f10),
                 "errors": jnp.sum(err)}
    else:
        scrubbed2, residual2, stats = R.scrub_ref(
            stored2, mask2, seed, thr01, thr10, e01, e10, nbits=nbits)

    stats = dict(stats)
    stats["bits_written"] = stats["flips01"] + stats["flips10"]
    # f32 (not i32): a >=256 MiB region holds >=2^31 bits (trace overflow)
    stats["bits_total"] = jnp.asarray(
        float(stored.size * jnp.dtype(stored.dtype).itemsize * 8),
        jnp.float32)

    ut = uint_type(stored.dtype)
    scrubbed = _from_lanes(scrubbed2.reshape(-1)[:n_lanes], stored.shape,
                           stored.dtype)
    residual = _from_lanes(residual2.reshape(-1)[:n_lanes], mask.shape, ut)
    return scrubbed, residual, stats
