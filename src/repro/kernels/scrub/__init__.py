"""Scrub (corrective re-write) kernel: Pallas implementation + jnp oracle.

Reached through the ``repro.memory`` backend registry
(``Backend.leaf_scrub``); see ``repro.reliability`` for the subsystem that
drives it.
"""
from repro.kernels.scrub.ops import scrub_write  # noqa: F401
