"""Oracle for the local_attention kernel: the framework's exact chunked
attention (models/attention.py) — independently tested against decode."""
from __future__ import annotations

import jax

from repro.models.attention import attention


def local_attention_ref(q, k, v, *, window: int, softcap: float = 0.0):
    return attention(q, k, v, window=window, causal=True,
                     softcap_val=softcap, dtype=q.dtype)
