from repro.kernels.local_attention.ops import local_attention  # noqa: F401
from repro.kernels.local_attention.ref import local_attention_ref  # noqa: F401
