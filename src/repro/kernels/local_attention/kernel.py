"""Pallas-TPU flash attention for causal sliding-window (SWA) layers.

Why a kernel: half of gemma2's layers (and all of h2o-danube's /
recurrentgemma's attention) are windowed — only a W-deep band of the score
matrix is live. The jnp path (models/attention.py) slices the key range per
q-chunk but still materializes (bq x W+bq) logits through HBM at long S.
This kernel keeps the whole online-softmax state in VMEM scratch and
streams K/V tiles, touching HBM O(S·h) instead of O(S·(W+bq)).

Mapping (TPU-idiomatic, not a CUDA port):
  grid = (B*H, nq, nk) — the last axis is the sequential K-tile walk, so
  scratch (m, l, acc) persists across it (TPU grids execute minor-most
  sequentially; interpret mode preserves the same semantics).
  For q-tile qi, K tiles cover positions [qi*bq - W_eff, qi*bq + bq):
  block index start_true may be negative at the left edge — the data index
  is clamped to 0 and a position-validity mask kills phantom contributions
  (tiles are aligned so a tile is either fully valid or fully phantom).
  GQA: the kv row for flat head index bh = b*H + head is
  b*K + head // (H//K), computed in the BlockSpec index_map — no K/V
  expansion through HBM.

Supports gemma2's attention logit softcap (tanh) inside the tile loop.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, bq: int, bk: int, w_eff: int, window: int, nk: int,
            scale: float, softcap: float):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # true (unclamped) start position of this K tile
    start_true = qi * bq - w_eff + ki * bk
    pos_q = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    pos_k = start_true + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    d = pos_q - pos_k
    mask = (pos_k >= 0) & (d >= 0) & (d < window)

    q = q_ref[0].astype(jnp.float32)          # (bq, h)
    k = k_ref[0].astype(jnp.float32)          # (bk, h)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                        # (bq,)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])            # (bq, bk)
    p = jnp.where(mask, p, 0.0)

    v = v_ref[0].astype(jnp.float32)           # (bk, h)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    m_ref[...] = m_cur

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def local_attention_kernel(
    q: jax.Array,   # (BH, S, h) — heads flattened into the batch dim
    k: jax.Array,   # (BK, S, h)
    v: jax.Array,
    *,
    num_q_heads: int,
    num_kv_heads: int,
    window: int,
    softcap: float = 0.0,
    bq: int = 256,
    bk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    BH, S, h = q.shape
    G = num_q_heads // num_kv_heads
    assert S % bq == 0 and bq % bk == 0, (S, bq, bk)
    w_eff = int(np.ceil(window / bk)) * bk     # tile-aligned window reach
    nq = S // bq
    nk = (w_eff + bq) // bk
    scale = h ** -0.5

    def q_index(bh, qi, ki):
        return (bh, qi, 0)

    def kv_index(bh, qi, ki):
        b = bh // num_q_heads
        head = bh % num_q_heads
        row = b * num_kv_heads + head // G
        start_blk = (qi * bq - w_eff) // bk + ki
        return (row, jnp.maximum(start_blk, 0), 0)

    grid = (BH, nq, nk)
    return pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, w_eff=w_eff, window=window,
                          nk=nk, scale=scale, softcap=softcap),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, h), q_index),
            pl.BlockSpec((1, bk, h), kv_index),
            pl.BlockSpec((1, bk, h), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, h), q_index),
        out_shape=jax.ShapeDtypeStruct((BH, S, h), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # m: running max
            pltpu.VMEM((bq,), jnp.float32),      # l: running denom
            pltpu.VMEM((bq, h), jnp.float32),    # acc: running numerator
        ],
        interpret=interpret,
    )(q, k, v)
