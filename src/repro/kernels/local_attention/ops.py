"""jit'd wrapper: (B, S, H, h) GQA tensors -> flash SWA attention."""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.local_attention import kernel as K


@functools.partial(jax.jit, static_argnames=("window", "softcap", "bq", "bk",
                                             "interpret"))
def local_attention(
    q: jax.Array,   # (B, S, H, h)
    k: jax.Array,   # (B, S, Kh, h)
    v: jax.Array,
    *,
    window: int,
    softcap: float = 0.0,
    bq: int = 256,
    bk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    B, S, H, h = q.shape
    Kh = k.shape[2]
    bq = min(bq, S)
    bk = min(bk, bq)
    if S % bq:
        bq = S  # smoke-scale fallback: single q tile
        bk = min(bk, bq)
    if bq % bk:
        bk = bq
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, h)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Kh, S, h)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Kh, S, h)
    of = K.local_attention_kernel(
        qf, kf, vf, num_q_heads=H, num_kv_heads=Kh,
        window=min(window, S), softcap=softcap, bq=bq, bk=bk,
        interpret=interpret)
    return of.reshape(B, H, S, h).transpose(0, 2, 1, 3)
