"""Pallas-TPU kernel for the EXTENT approximate write path.

Fuses, in one HBM pass over (old, new):
    XOR bit-diff -> per-bit-plane stochastic write failure -> stored word
    + per-block energy / flip / error reductions.

Why a kernel: the write path is purely memory-bound (O(bytes) work, zero
matmul). Composed as jnp ops it materializes the (elements x nbits) unpacked
bit tensor (16-32x write amplification through HBM); fused it runs at HBM
streaming bandwidth with all bit algebra in VREGs and the stats reduced in
VMEM scratch. This is the TPU re-thinking of the paper's per-row driver
bank: the "64 parallel drivers per word" become lane-parallel bit ops over a
(block_r, block_c) VMEM tile.

RNG: counter-based murmur3-style hash of (seed, element index, bit plane) —
no state, identical on TPU hardware and in interpret mode, and reproducible
from ref.py (the pure-jnp oracle implements the same hash bit-exactly).

Layout: operands are bitcast to uint32 lanes *outside* the kernel (ops.py):
uint32 is the native VPU lane width; bf16 tensors pack pairs of elements
into one lane, f32 maps 1:1. Block shape defaults to (256, 512) lanes =
512 KiB per uint32 buffer — 3 buffers (old/new/stored) plus unrolled f32
temporaries stay well under the 16 MiB VMEM budget. The RNG hashes the
*flat* lane index (row * cols_total + col), so results are invariant to
how ops.py partitions the lane vector into a (rows, cols) grid — small
tensors get right-sized grids instead of full-block padding, bit-identical
to any other partition.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

import numpy as np

# murmur3 finalizer constants (numpy scalars: safe to close over in a
# pallas kernel body — jnp arrays would be captured consts, which is an error)
_M1 = np.uint32(0x85EBCA6B)
_M2 = np.uint32(0xC2B2AE35)
_K_ELEM = np.uint32(2654435761)   # Knuth multiplicative hash
_K_BIT = np.uint32(0x9E3779B9)    # golden-ratio increment per bit plane

DEFAULT_BLOCK = (256, 512)


def _hash_u32(x: jax.Array) -> jax.Array:
    """murmur3 fmix32: avalanching 32-bit hash, vectorizes on the VPU."""
    x = x ^ (x >> 16)
    x = x * _M1
    x = x ^ (x >> 13)
    x = x * _M2
    x = x ^ (x >> 16)
    return x


def uniform_bits(seed: jax.Array, elem_idx: jax.Array, bit: int) -> jax.Array:
    """Deterministic uniform uint32 for (seed, element, bit-plane)."""
    h = (elem_idx.astype(jnp.uint32) * _K_ELEM
         ^ (jnp.uint32(bit) * _K_BIT) ^ seed.astype(jnp.uint32))
    return _hash_u32(h)


def _kernel(
    old_ref, new_ref, seed_ref, thr01_ref, thr10_ref, e01_ref, e10_ref,
    stored_ref, energy_ref, flips01_ref, flips10_ref, errors_ref,
    *, nbits: int, block: Tuple[int, int], cols_total: int,
):
    r, c = pl.program_id(0), pl.program_id(1)
    old = old_ref[...]
    new = new_ref[...]
    seed = seed_ref[0]

    # global flat element index of each lane in this block
    row0 = r * block[0]
    col0 = c * block[1]
    rows = jax.lax.broadcasted_iota(jnp.uint32, block, 0) + jnp.uint32(row0)
    cols = jax.lax.broadcasted_iota(jnp.uint32, block, 1) + jnp.uint32(col0)
    elem = rows * jnp.uint32(cols_total) + cols

    diff = old ^ new
    one = jnp.uint32(1)

    fail_acc = jnp.zeros(block, jnp.uint32)
    energy = jnp.zeros(block, jnp.float32)
    n01 = jnp.zeros(block, jnp.uint32)
    n10 = jnp.zeros(block, jnp.uint32)
    nerr = jnp.zeros(block, jnp.uint32)

    for b in range(nbits):  # static unroll: nbits is 16 or 32
        bitmask = one << b
        flip = (diff & bitmask) != 0
        to_ap = flip & ((new & bitmask) != 0)          # 0->1 writes
        u = uniform_bits(seed, elem, b)
        thr = jnp.where(to_ap, thr01_ref[b], thr10_ref[b])
        fail = flip & (u < thr)
        fail_acc = fail_acc | jnp.where(fail, bitmask, jnp.uint32(0))
        e_bit = jnp.where(to_ap, e01_ref[b], e10_ref[b])
        energy = energy + jnp.where(flip, e_bit, 0.0)
        n01 = n01 + to_ap.astype(jnp.uint32)
        n10 = n10 + (flip & ~to_ap).astype(jnp.uint32)
        nerr = nerr + fail.astype(jnp.uint32)

    stored_ref[...] = new ^ fail_acc
    energy_ref[0, 0] = jnp.sum(energy)
    flips01_ref[0, 0] = jnp.sum(n01.astype(jnp.int32))
    flips10_ref[0, 0] = jnp.sum(n10.astype(jnp.int32))
    errors_ref[0, 0] = jnp.sum(nerr.astype(jnp.int32))


def extent_write_kernel(
    old_u32: jax.Array,      # (R, C) uint32 lanes, R % block[0] == 0 etc.
    new_u32: jax.Array,
    seed: jax.Array,         # (1,) uint32
    thr01: jax.Array,        # (nbits,) uint32 failure thresholds (wer * 2^32)
    thr10: jax.Array,
    e01: jax.Array,          # (nbits,) f32 per-flip energies (pJ)
    e10: jax.Array,
    *,
    nbits: int,
    block: Tuple[int, int] = DEFAULT_BLOCK,
    interpret: bool = True,  # CPU container: validate via interpreter
):
    """Returns (stored (R,C) uint32, energy (gr,gc) f32, flips01, flips10,
    errors (gr,gc) i32). Stats are per-block partial sums."""
    R, C = old_u32.shape
    assert R % block[0] == 0 and C % block[1] == 0, (old_u32.shape, block)
    grid = (R // block[0], C // block[1])

    vec_spec = pl.BlockSpec((nbits,), lambda r, c: (0,))
    stat_spec = pl.BlockSpec((1, 1), lambda r, c: (r, c))
    data_spec = pl.BlockSpec(block, lambda r, c: (r, c))

    return pl.pallas_call(
        functools.partial(_kernel, nbits=nbits, block=block, cols_total=C),
        grid=grid,
        in_specs=[
            data_spec, data_spec,
            pl.BlockSpec((1,), lambda r, c: (0,)),   # seed
            vec_spec, vec_spec, vec_spec, vec_spec,
        ],
        out_specs=[
            data_spec, stat_spec, stat_spec, stat_spec, stat_spec,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, C), jnp.uint32),
            jax.ShapeDtypeStruct(grid, jnp.float32),
            jax.ShapeDtypeStruct(grid, jnp.int32),
            jax.ShapeDtypeStruct(grid, jnp.int32),
            jax.ShapeDtypeStruct(grid, jnp.int32),
        ],
        interpret=interpret,
    )(old_u32, new_u32, seed, thr01, thr10, e01, e10)
