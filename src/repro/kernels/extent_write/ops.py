"""Public jit'd wrapper for the extent_write kernel.

Handles dtype bitcasting (int8/uint8 pack 4 elements per uint32 lane,
bf16/f16 pack 2, f32/int32 map 1:1), padding to block multiples,
level-table -> threshold conversion, and reduction of per-block stats.
``use_kernel=False`` routes to the ref oracle (same semantics) — the
default on CPU hosts where only interpret-mode execution is available and
speed doesn't matter.

This module is kernel-internal plumbing: everything outside
``repro/kernels`` and ``repro/memory`` goes through the backend registry in
``repro.memory`` instead of calling ``extent_write`` directly.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import write_driver
from repro.core.priority import Priority, bitplane_priorities
from repro.kernels.extent_write import kernel as K
from repro.kernels.extent_write import ref as R


@functools.lru_cache(maxsize=64)
def level_vectors(dtype, level: Priority,
                  cfg: Optional[write_driver.DriverConfig] = None):
    """Per-bit-plane (thr01, thr10, e01, e10) for one element dtype, with the
    bit-plane priority policy applied, then widened to the uint32 lane
    layout (2x16-bit elements per lane for 16-bit dtypes).

    Public so jit-resident callers (serve engine, approx_store's lane path)
    can resolve a tensor's priority to driver vectors once, outside the
    traced region: the vectors are plain arrays, so changing a tensor's
    priority swaps constants without retracing the write computation.
    The driver calibration is Python-float code, so it is forced to
    compile-time evaluation — safe to call (via lru_cache, once) even while
    tracing an enclosing jit."""
    with jax.ensure_compile_time_eval():
        table = write_driver.level_table(cfg or write_driver.DriverConfig())
        codes = bitplane_priorities(dtype, Priority.coerce(level))  # (ebits,)
        wer01 = np.asarray(table["wer01"])[codes]
        wer10 = np.asarray(table["wer10"])[codes]
        e01 = np.asarray(table["e01"])[codes]
        e10 = np.asarray(table["e10"])[codes]
        ebits = codes.shape[0]
        if ebits in (8, 16):  # 4 (or 2) elements per uint32 lane: tile the
            reps = 32 // ebits  # per-element bit pattern across the lane
            wer01 = np.tile(wer01, reps)
            wer10 = np.tile(wer10, reps)
            e01 = np.tile(e01, reps)
            e10 = np.tile(e10, reps)
        to_thr = lambda w: (np.clip(w, 0.0, 1.0) * 2**32).astype(
            np.uint64).clip(0, 2**32 - 1).astype(np.uint32)
        return (jnp.asarray(to_thr(wer01)), jnp.asarray(to_thr(wer10)),
                jnp.asarray(e01, jnp.float32), jnp.asarray(e10, jnp.float32))


_level_vectors = level_vectors  # backwards-compatible alias


def _to_lanes(x: jax.Array) -> Tuple[jax.Array, int]:
    """Bitcast any 1/2/4-byte tensor into a flat uint32 lane vector
    (little-endian element packing for the sub-word dtypes)."""
    nbytes = jnp.dtype(x.dtype).itemsize
    if nbytes == 4:
        u = jax.lax.bitcast_convert_type(x, jnp.uint32).reshape(-1)
        return u, x.size
    if nbytes == 2:
        u16 = jax.lax.bitcast_convert_type(x, jnp.uint16).reshape(-1)
        if u16.size % 2:
            u16 = jnp.concatenate([u16, jnp.zeros((1,), jnp.uint16)])
        pair = u16.reshape(-1, 2).astype(jnp.uint32)
        return pair[:, 0] | (pair[:, 1] << 16), x.size
    assert nbytes == 1, x.dtype
    u8 = jax.lax.bitcast_convert_type(x, jnp.uint8).reshape(-1)
    pad = (-u8.size) % 4
    if pad:
        u8 = jnp.concatenate([u8, jnp.zeros((pad,), jnp.uint8)])
    quad = u8.reshape(-1, 4).astype(jnp.uint32)
    return (quad[:, 0] | (quad[:, 1] << 8) | (quad[:, 2] << 16)
            | (quad[:, 3] << 24)), x.size


def _from_lanes(u: jax.Array, shape, dtype) -> jax.Array:
    nbytes = jnp.dtype(dtype).itemsize
    n = int(np.prod(shape))
    if nbytes == 4:
        return jax.lax.bitcast_convert_type(u[:n], dtype).reshape(shape)
    if nbytes == 2:
        lo = (u & 0xFFFF).astype(jnp.uint16)
        hi = (u >> 16).astype(jnp.uint16)
        u16 = jnp.stack([lo, hi], axis=-1).reshape(-1)[:n]
        return jax.lax.bitcast_convert_type(u16, dtype).reshape(shape)
    assert nbytes == 1, dtype
    u8 = jnp.stack([(u >> (8 * k)).astype(jnp.uint8) for k in range(4)],
                   axis=-1).reshape(-1)[:n]
    return jax.lax.bitcast_convert_type(u8, dtype).reshape(shape)


def extent_write(
    key: jax.Array,
    old: jax.Array,
    new: jax.Array,
    *,
    level: Priority = Priority.LOW,
    block: Tuple[int, int] = K.DEFAULT_BLOCK,
    use_kernel: bool = True,
    interpret: bool = True,
    vectors: Optional[Tuple[jax.Array, jax.Array, jax.Array, jax.Array]] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """EXTENT approximate write of ``new`` over ``old`` (same shape/dtype).

    Returns (stored, stats{energy_pj, flips01, flips10, errors,
    bits_written, bits_total}). ``bits_total`` counts only real element
    bits — padding lanes added to reach block multiples are excluded, so
    partial blocks account exactly like full ones.

    The driver level table is resolved eagerly (it is Python-float
    calibration code); the data path below is jitted. Callers already
    inside a jit trace may pass precomputed ``vectors`` (see
    ``level_vectors``) to route per-tensor priorities without touching the
    level table: the vectors are ordinary operands, so two tensors at
    different priorities share one compiled computation.
    """
    assert old.shape == new.shape and old.dtype == new.dtype
    if vectors is None:
        vectors = level_vectors(old.dtype, Priority.coerce(level))
    thr01, thr10, e01, e10 = vectors
    return _extent_write_jit(key, old, new, thr01, thr10, e01, e10,
                             block=block, use_kernel=use_kernel,
                             interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block", "use_kernel",
                                             "interpret"))
def _extent_write_jit(
    key, old, new, thr01, thr10, e01, e10, *,
    block: Tuple[int, int], use_kernel: bool, interpret: bool,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    nbits = int(thr01.shape[0])
    seed = jax.random.bits(key, (1,), jnp.uint32)

    old_u, _ = _to_lanes(old)
    new_u, _ = _to_lanes(new)
    n_lanes = old_u.size

    # The counter RNG hashes the *flat* lane index, so the (rows, cols)
    # layout is free to follow the tensor instead of the other way around:
    # results are bit-identical for any block partition, and small tensors
    # (a serving cache leaf is a few thousand lanes) must not pay a full
    # (256 x 512)-lane pad — only the rows are padded, to the row-block.
    if use_kernel:
        cols = block[1]
        rows_used = max(1, -(-n_lanes // cols))
        block_r = min(block[0], rows_used)
        rows = -(-rows_used // block_r) * block_r
    else:
        # pure-jnp ref: no grid constraints at all, one row, zero pad
        cols = n_lanes if n_lanes else 1
        rows = 1
    pad = rows * cols - n_lanes
    # padding lanes: old == new == 0 -> no flips, no energy, no failures
    old2 = jnp.concatenate(
        [old_u, jnp.zeros((pad,), jnp.uint32)]).reshape(rows, cols)
    new2 = jnp.concatenate(
        [new_u, jnp.zeros((pad,), jnp.uint32)]).reshape(rows, cols)

    if use_kernel:
        stored2, energy, f01, f10, err = K.extent_write_kernel(
            old2, new2, seed, thr01, thr10, e01, e10,
            nbits=nbits, block=(min(block[0], rows), cols),
            interpret=interpret)
        stats = {"energy_pj": jnp.sum(energy),
                 "flips01": jnp.sum(f01), "flips10": jnp.sum(f10),
                 "errors": jnp.sum(err)}
    else:
        stored2, stats = R.extent_write_ref(
            old2, new2, seed, thr01, thr10, e01, e10, nbits=nbits)

    # partial-block accounting: padding lanes are (0 -> 0) writes, so they
    # contribute no flips/energy/errors above; bits_total likewise counts
    # only the real element bits, never the pad. f32 (not i32): a >=256 MiB
    # tensor holds >=2^31 bits, which would overflow at trace time.
    stats = dict(stats)
    stats["bits_written"] = stats["flips01"] + stats["flips10"]
    stats["bits_total"] = jnp.asarray(
        float(old.size * jnp.dtype(old.dtype).itemsize * 8), jnp.float32)

    stored_u = stored2.reshape(-1)[:n_lanes]
    stored = _from_lanes(stored_u, old.shape, old.dtype)
    return stored, stats
