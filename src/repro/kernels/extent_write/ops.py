"""Public jit'd wrapper for the extent_write kernel.

Handles dtype bitcasting (bf16/f16 pack 2 elements per uint32 lane, f32/int32
map 1:1), padding to block multiples, level-table -> threshold conversion,
and reduction of per-block stats. ``use_kernel=False`` routes to the ref
oracle (same semantics) — the default on CPU hosts where only interpret-mode
execution is available and speed doesn't matter.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import write_driver
from repro.core.priority import Priority, bitplane_priorities
from repro.kernels.extent_write import kernel as K
from repro.kernels.extent_write import ref as R


@functools.lru_cache(maxsize=64)
def _level_vectors(dtype, level: Priority,
                   cfg: Optional[write_driver.DriverConfig] = None):
    """Per-bit-plane (thr01, thr10, e01, e10) for one element dtype, with the
    bit-plane priority policy applied, then widened to the uint32 lane
    layout (2x16-bit elements per lane for 16-bit dtypes)."""
    table = write_driver.level_table(cfg or write_driver.DriverConfig())
    codes = bitplane_priorities(dtype, Priority.coerce(level))  # (ebits,)
    wer01 = np.asarray(table["wer01"])[codes]
    wer10 = np.asarray(table["wer10"])[codes]
    e01 = np.asarray(table["e01"])[codes]
    e10 = np.asarray(table["e10"])[codes]
    ebits = codes.shape[0]
    if ebits == 16:  # two elements per uint32 lane: repeat the bit pattern
        wer01 = np.concatenate([wer01, wer01])
        wer10 = np.concatenate([wer10, wer10])
        e01 = np.concatenate([e01, e01])
        e10 = np.concatenate([e10, e10])
    to_thr = lambda w: (np.clip(w, 0.0, 1.0) * 2**32).astype(np.uint64).clip(
        0, 2**32 - 1).astype(np.uint32)
    return (jnp.asarray(to_thr(wer01)), jnp.asarray(to_thr(wer10)),
            jnp.asarray(e01, jnp.float32), jnp.asarray(e10, jnp.float32))


def _to_lanes(x: jax.Array) -> Tuple[jax.Array, int]:
    """Bitcast any 2/4-byte tensor into a flat uint32 lane vector."""
    nbytes = jnp.dtype(x.dtype).itemsize
    if nbytes == 4:
        u = jax.lax.bitcast_convert_type(x, jnp.uint32).reshape(-1)
        return u, x.size
    assert nbytes == 2, x.dtype
    u16 = jax.lax.bitcast_convert_type(x, jnp.uint16).reshape(-1)
    if u16.size % 2:
        u16 = jnp.concatenate([u16, jnp.zeros((1,), jnp.uint16)])
    pair = u16.reshape(-1, 2).astype(jnp.uint32)
    return pair[:, 0] | (pair[:, 1] << 16), x.size


def _from_lanes(u: jax.Array, shape, dtype) -> jax.Array:
    nbytes = jnp.dtype(dtype).itemsize
    n = int(np.prod(shape))
    if nbytes == 4:
        return jax.lax.bitcast_convert_type(u[:n], dtype).reshape(shape)
    lo = (u & 0xFFFF).astype(jnp.uint16)
    hi = (u >> 16).astype(jnp.uint16)
    u16 = jnp.stack([lo, hi], axis=-1).reshape(-1)[:n]
    return jax.lax.bitcast_convert_type(u16, dtype).reshape(shape)


def extent_write(
    key: jax.Array,
    old: jax.Array,
    new: jax.Array,
    *,
    level: Priority = Priority.LOW,
    block: Tuple[int, int] = K.DEFAULT_BLOCK,
    use_kernel: bool = True,
    interpret: bool = True,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """EXTENT approximate write of ``new`` over ``old`` (same shape/dtype).

    Returns (stored, stats{energy_pj, flips01, flips10, errors}).
    The driver level table is resolved eagerly (it is Python-float
    calibration code); the data path below is jitted.
    """
    assert old.shape == new.shape and old.dtype == new.dtype
    thr01, thr10, e01, e10 = _level_vectors(old.dtype, Priority.coerce(level))
    return _extent_write_jit(key, old, new, thr01, thr10, e01, e10,
                             block=block, use_kernel=use_kernel,
                             interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block", "use_kernel",
                                             "interpret"))
def _extent_write_jit(
    key, old, new, thr01, thr10, e01, e10, *,
    block: Tuple[int, int], use_kernel: bool, interpret: bool,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    nbits = int(thr01.shape[0])
    seed = jax.random.bits(key, (1,), jnp.uint32)

    old_u, _ = _to_lanes(old)
    new_u, _ = _to_lanes(new)
    n_lanes = old_u.size
    bc = block[0] * block[1]
    pad = (-n_lanes) % bc
    # padding lanes: old == new == 0 -> no flips, no energy, no failures
    old_p = jnp.concatenate([old_u, jnp.zeros((pad,), jnp.uint32)])
    new_p = jnp.concatenate([new_u, jnp.zeros((pad,), jnp.uint32)])
    rows = old_p.size // block[1]
    old2 = old_p.reshape(rows, block[1])
    new2 = new_p.reshape(rows, block[1])

    if use_kernel:
        stored2, energy, f01, f10, err = K.extent_write_kernel(
            old2, new2, seed, thr01, thr10, e01, e10,
            nbits=nbits, block=(min(block[0], rows), block[1]),
            interpret=interpret)
        stats = {"energy_pj": jnp.sum(energy),
                 "flips01": jnp.sum(f01), "flips10": jnp.sum(f10),
                 "errors": jnp.sum(err)}
    else:
        stored2, stats = R.extent_write_ref(
            old2, new2, seed, thr01, thr10, e01, e10, nbits=nbits)

    stored_u = stored2.reshape(-1)[:n_lanes]
    stored = _from_lanes(stored_u, old.shape, old.dtype)
    return stored, stats
