from repro.kernels.extent_write.ops import extent_write, level_vectors  # noqa: F401
from repro.kernels.extent_write.kernel import extent_write_kernel  # noqa: F401
from repro.kernels.extent_write.ref import extent_write_ref  # noqa: F401
