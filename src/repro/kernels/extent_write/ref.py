"""Pure-jnp oracle for the extent_write Pallas kernel.

Implements the identical semantics (same murmur3 counter RNG, same bit
algebra, same stats) with plain jnp ops over the unpacked (elements x nbits)
tensor — the slow-but-obviously-correct reference every kernel test
asserts against bit-exactly.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.extent_write.kernel import _hash_u32, _K_BIT, _K_ELEM


def _uniform_bits_all(seed: jax.Array, elem: jax.Array,
                      nbits: int) -> jax.Array:
    """(R, C, nbits) counter-RNG draws — the vectorized form of the
    kernel's per-bit-plane ``uniform_bits``, bit-identical by construction
    (same hash over (seed, flat element index, bit plane))."""
    bits = jnp.arange(nbits, dtype=jnp.uint32)
    h = (elem.astype(jnp.uint32)[..., None] * _K_ELEM
         ^ (bits * _K_BIT) ^ seed.astype(jnp.uint32))
    return _hash_u32(h)


def extent_write_ref(
    old_u32: jax.Array,   # (R, C) uint32
    new_u32: jax.Array,
    seed: jax.Array,      # (1,) uint32
    thr01: jax.Array,     # (nbits,) uint32
    thr10: jax.Array,
    e01: jax.Array,       # (nbits,) f32
    e10: jax.Array,
    *,
    nbits: int,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    R, C = old_u32.shape
    elem = (jnp.arange(R, dtype=jnp.uint32)[:, None] * jnp.uint32(C)
            + jnp.arange(C, dtype=jnp.uint32)[None, :])

    bits = jnp.arange(nbits, dtype=jnp.uint32)
    mask = (jnp.uint32(1) << bits)                       # (nbits,)
    diff = old_u32 ^ new_u32
    flip = (diff[..., None] & mask) != 0                  # (R,C,nbits)
    to_ap = flip & ((new_u32[..., None] & mask) != 0)

    u = _uniform_bits_all(seed[0], elem, nbits)
    thr = jnp.where(to_ap, thr01, thr10)
    fail = flip & (u < thr)

    fail_mask = jnp.sum(jnp.where(fail, mask, jnp.uint32(0)), axis=-1,
                        dtype=jnp.uint32)
    stored = new_u32 ^ fail_mask

    e_bit = jnp.where(to_ap, e01, e10)
    stats = {
        "energy_pj": jnp.sum(jnp.where(flip, e_bit, 0.0), dtype=jnp.float32),
        "flips01": jnp.sum(to_ap, dtype=jnp.int32),
        "flips10": jnp.sum(flip & ~to_ap, dtype=jnp.int32),
        "errors": jnp.sum(fail, dtype=jnp.int32),
    }
    return stored, stats
